"""§Roofline table: aggregates artifacts/dryrun/*.json into the
per-(arch × shape × mesh) roofline report (EXPERIMENTS.md)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from benchmarks.common import Row

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                          "dryrun")


def load_records(mesh: str = None) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def table_rows(mesh: str = "16x16") -> List[str]:
    header = ("arch,shape,mesh,ok,t_compute_s,t_memory_s,"
              "t_collective_s,bottleneck,useful_flop_ratio,"
              "hbm_bytes_per_chip,what_moves_it")
    out = [header]
    for r in load_records(mesh):
        if not r.get("ok"):
            out.append(f"{r['arch']},{r['shape']},{r['mesh']},FAIL,,,,,"
                       f",,{r.get('error', '')[:80]}")
            continue
        rl = r["roofline"]
        mem = r.get("memory") or {}
        hbm = (mem.get("argument_size_in_bytes") or 0) + \
            (mem.get("temp_size_in_bytes") or 0)
        hint = {
            "compute": "fewer expressed FLOPs (causal fold / SA routing)",
            "memory": "smaller resident KV (ring caches) / fused ops",
            "collective": "shard_map overlap / 2D-sharding re-layout",
        }[rl["bottleneck"]]
        ufr = rl.get("useful_flop_ratio")
        out.append(
            f"{r['arch']},{r['shape']},{r['mesh']},OK,"
            f"{rl['t_compute_s']:.3e},{rl['t_memory_s']:.3e},"
            f"{rl['t_collective_s']:.3e},{rl['bottleneck']},"
            f"{ufr if ufr is None else round(ufr, 3)},{hbm},{hint}")
    return out


def run() -> List[Row]:
    rows = []
    for mesh in ("16x16", "2x16x16"):
        recs = load_records(mesh)
        ok = sum(1 for r in recs if r.get("ok"))
        rows.append(Row(f"roofline/{mesh}", 0.0,
                        f"{ok}/{len(recs)} compiled"))
    return rows


def main() -> None:
    for mesh in ("16x16", "2x16x16"):
        rows = table_rows(mesh)
        if len(rows) > 1:
            path = os.path.join(DRYRUN_DIR, f"roofline_{mesh}.csv")
            with open(path, "w") as f:
                f.write("\n".join(rows) + "\n")
            print("\n".join(rows))
            print(f"→ {path}")


if __name__ == "__main__":
    main()
