"""Decode-loop speedup: per-step host loop vs device-resident scan,
plus the pooled-kernel leg.

The seed engine dispatched one jit call per generated token and synced
the sampled token to host every step; ``decode_many`` fuses
sample→decode for all steps into one executable (DESIGN.md §Serving).
This bench measures decode tokens/sec and compiled-dispatch counts for
both drivers across routing patterns (all-FA, all-SA, mixed).

The pooled leg drives the continuous-batching scheduler over a
mixed-length slot pool twice — dense pooled attention vs the batched
Pallas decode kernel (``make_kernel_decode_attn``) — asserts the token
streams are identical, and times both drains.  On CPU the kernel runs
in interpret mode, so the timing there is advisory; the analytic
expressed-cost sweep (``repro.launch.hlo_costs.pooled_decode_report``)
is embedded in ``BENCH_decode.json`` to carry the HBM-scaling claim.
"""
from __future__ import annotations

import itertools
import json
import os
import sys
import time
from functools import partial
from typing import List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import CACHE_DIR, Row, bench_cfg, time_call
from repro.kernels.decode_attention import make_kernel_decode_attn
from repro.launch.hlo_costs import pooled_decode_report
from repro.models import model as MD
from repro.serve import Request, ServeEngine
from repro.serve.engine import repack_caches

B, S = 2, 48


def _patterns(cfg):
    kinds = cfg.layer_kinds
    fa = tuple("fa" if k == "attn" else None for k in kinds)
    sa = tuple("sa" if k == "attn" else None for k in kinds)
    flip, mixed = True, []
    for k in kinds:
        mixed.append(("fa" if flip else "sa") if k == "attn" else None)
        flip = not flip if k == "attn" else flip
    return [("all-fa", fa), ("all-sa", sa), ("mixed", tuple(mixed))]


def run_pooled(n_steps: int = 8, iters: int = 2, n_reqs: int = 4):
    """Mixed-length slot pool through the scheduler, dense vs kernel.

    Returns (rows, results): per-leg timing Rows and the
    BENCH_decode.json entries (with the decode-kernel drain summary so
    the artifact records that the kernel actually fired)."""
    cfg = bench_cfg()
    params = MD.init_params(jax.random.key(0), cfg)
    max_len = 64
    rng = np.random.default_rng(0)
    lens = (12, 20, 28, 36)
    toks = [rng.integers(0, cfg.vocab_size, size=lens[i % len(lens)]
                         ).astype(np.int32) for i in range(n_reqs)]
    kernel = make_kernel_decode_attn(block_k=16, min_len=16)
    legs, streams = [], {}
    for leg, decode_attn in (("dense", None), ("kernel", kernel)):
        eng = ServeEngine(params, cfg, max_len=max_len,
                          decode_attn=decode_attn)
        fresh_rid = itertools.count()

        def drain_once(eng=eng, fresh_rid=fresh_rid):
            base = next(fresh_rid) * 100
            # a drained scheduler no longer ticks — start a fresh one
            # (the decode jit cache lives on the engine, so this does
            # not re-trace anything)
            eng._scheduler = None
            eng.scheduler(slots_per_bucket=3, chunk=4)
            for i, t in enumerate(toks):
                eng.submit(Request(rid=base + i, tokens=t,
                                   n_steps=n_steps))
            out = eng.drain()
            return [np.asarray(out[base + i].tokens)
                    for i in range(len(toks))]

        streams[leg] = drain_once()
        legs.append((leg, eng, drain_once))
    for a, b in zip(streams["dense"], streams["kernel"]):
        assert np.array_equal(a, b), "pooled kernel diverged from dense"
    summary = legs[1][1].decode_kernel_summary()
    assert summary["hit_layers"] > 0, summary
    rows, results = [], []
    for leg, eng, drain_once in legs:
        us = time_call(drain_once, warmup=1, iters=iters)
        tps = n_reqs * n_steps / (us / 1e6)
        results.append({
            "leg": f"pooled-{leg}", "n_steps": n_steps,
            "n_requests": n_reqs, "lens": list(lens[:n_reqs]),
            "drain_us": us, "tokens_per_sec": tps,
            "decode_kernel": eng.decode_kernel_summary(),
        })
        rows.append(Row(f"decode-speedup/pooled/{leg}", us,
                        f"tps={tps:.0f};parity=ok"))
    return rows, results


def run(n_steps: int = 64, iters: int = 5) -> List[Row]:
    cfg = bench_cfg()
    params = MD.init_params(jax.random.key(0), cfg)
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=(B, S)), jnp.int32)
    max_len = S + n_steps + 2
    eng = ServeEngine(params, cfg, max_len=max_len)
    scan_fn = jax.jit(partial(MD.decode_many, cfg=cfg),
                      static_argnames=("n_steps", "greedy"))
    rows: List[Row] = []
    results = []
    for name, pattern in _patterns(cfg):
        pf = eng._prefill(params=params, tokens=toks, routing_ctx="fa_only",
                          prefix_embeddings=None, encoder_frames=None)

        def fresh_caches():
            return repack_caches(cfg, pf.caches, pattern, S, max_len)

        step_fn = jax.jit(lambda token, caches, pos: MD.decode_step(
            params, cfg, token, caches, pattern, pos))

        def looped():
            # the seed driver: one dispatch + one host sync per token
            logits, caches = pf.logits, fresh_caches()
            out = []
            for i in range(n_steps):
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                out.append(np.asarray(nxt))
                logits, caches = step_fn(nxt[:, None], caches,
                                         jnp.int32(S + i))
            return np.stack(out, 1)

        def scanned():
            toks_out, _, _ = scan_fn(
                params=params, logits=pf.logits, caches=fresh_caches(),
                pos=jnp.int32(S), rng=jax.random.key(0),
                n_steps=n_steps, greedy=True, fa_heads=None)
            return np.asarray(toks_out)

        assert np.array_equal(looped(), scanned()), name
        us_loop = time_call(looped, iters=iters)
        us_scan = time_call(scanned, iters=iters)
        tps_loop = B * n_steps / (us_loop / 1e6)
        tps_scan = B * n_steps / (us_scan / 1e6)
        speedup = us_loop / us_scan
        results.append({
            "pattern": name, "n_steps": n_steps, "batch": B,
            "looped_us": us_loop, "scanned_us": us_scan,
            "looped_tokens_per_sec": tps_loop,
            "scanned_tokens_per_sec": tps_scan,
            "speedup": speedup,
            "looped_dispatches": n_steps, "scanned_dispatches": 1,
        })
        rows.append(Row(f"decode-speedup/{name}/looped", us_loop,
                        f"tps={tps_loop:.0f};dispatches={n_steps}"))
        rows.append(Row(f"decode-speedup/{name}/scanned", us_scan,
                        f"tps={tps_scan:.0f};dispatches=1;"
                        f"speedup={speedup:.2f}x"))
    pooled_rows, pooled_results = run_pooled(
        n_steps=4 if n_steps <= 8 else 8,
        iters=1 if n_steps <= 8 else iters)
    rows.extend(pooled_rows)
    cost_report = pooled_decode_report(cfg, max_len=max_len, batch=4,
                                       block_k=16)
    os.makedirs(CACHE_DIR, exist_ok=True)
    with open(os.path.join(CACHE_DIR, "BENCH_decode.json"), "w") as f:
        json.dump({"timestamp": time.time(), "device":
                   jax.default_backend(), "results": results,
                   "pooled_results": pooled_results,
                   "pooled_cost_report": cost_report}, f, indent=2)
    return rows


def main() -> None:
    smoke = "--smoke" in sys.argv
    rows = run(n_steps=8, iters=2) if smoke else run()
    for r in rows:
        print(r.csv())
    if smoke:
        # advisory, not a CI gate: shared CI runners make short-run
        # decode timings too noisy to fail on — the WARN line is for
        # humans reading the log (correctness IS gated: run() asserts
        # looped and scanned tokens are identical)
        data = json.load(open(os.path.join(CACHE_DIR, "BENCH_decode.json")))
        slow = [r["pattern"] for r in data["results"] if r["speedup"] < 1.0]
        print("# smoke ok" if not slow else f"# WARN scan slower on {slow}")


if __name__ == "__main__":
    main()
