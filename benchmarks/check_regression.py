"""Bench regression gate: fresh BENCH_*.json vs committed baselines.

    python benchmarks/check_regression.py            # gate (CI step)
    python benchmarks/check_regression.py --update   # re-baseline

Compares every ``BENCH_*.json`` under ``benchmarks/baselines/`` against
the same-named fresh artifact under ``artifacts/bench/`` (written by
the bench smokes that just ran).  Two metric families are gated, found
by key name anywhere in the JSON tree:

  * ``tokens_per_sec``  — throughput, regression = (base - fresh)/base
  * ``ttft_p50_s``      — p50 time-to-first-token, regression =
                          (fresh - base)/base

Thresholds: a regression past ``--warn`` (default 10%) prints a WARN
line; past ``--fail`` (default 25%) the gate exits 1.  Improvements
and sub-warn drift print as ok.  Baselines are recorded on the same
class of runner the gate runs on (CI smoke shapes) — the generous fail
bar absorbs shared-runner noise while still catching the 2× cliffs a
scheduling or dispatch regression causes.

Coverage is explicit, never silent: baseline files with no fresh
artifact (bench didn't run) and fresh artifacts with no baseline
(not yet gated) are listed in the output.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
from typing import Dict, List, Tuple

BENCH_DIR = os.path.dirname(os.path.abspath(__file__))
BASELINE_DIR = os.path.join(BENCH_DIR, "baselines")
FRESH_DIR = os.path.join(BENCH_DIR, "..", "artifacts", "bench")

# key → direction: +1 means higher-is-better (regression when fresh
# drops), -1 means lower-is-better (regression when fresh rises)
GATED_METRICS = {"tokens_per_sec": +1, "ttft_p50_s": -1}


def _flatten(node, prefix: str = "") -> Dict[str, float]:
    """{json-path: value} for every gated numeric leaf under ``node``."""
    out: Dict[str, float] = {}
    if isinstance(node, dict):
        for k, v in node.items():
            path = f"{prefix}.{k}" if prefix else k
            if k in GATED_METRICS and isinstance(v, (int, float)):
                out[path] = float(v)
            else:
                out.update(_flatten(v, path))
    elif isinstance(node, list):
        for i, v in enumerate(node):
            out.update(_flatten(v, f"{prefix}[{i}]"))
    return out


def compare(base: Dict, fresh: Dict, *, warn: float,
            fail: float) -> Tuple[List[str], List[str], List[str]]:
    """(ok, warned, failed) report lines for one baseline/fresh pair."""
    ok, warned, failed = [], [], []
    b, f = _flatten(base), _flatten(fresh)
    for path in sorted(b):
        if path not in f:
            warned.append(f"WARN {path}: in baseline but not in fresh "
                          f"artifact (metric renamed or leg dropped?)")
            continue
        key = path.rsplit(".", 1)[-1]
        sign = GATED_METRICS[key]
        bv, fv = b[path], f[path]
        if bv == 0 or not (bv == bv and fv == fv):  # zero or NaN base
            ok.append(f"ok   {path}: baseline={bv:g} fresh={fv:g} "
                      f"(not comparable, skipped)")
            continue
        reg = sign * (bv - fv) / abs(bv)
        line = (f"{path}: baseline={bv:.4g} fresh={fv:.4g} "
                f"regression={reg:+.1%}")
        if reg >= fail:
            failed.append(f"FAIL {line} (>= {fail:.0%})")
        elif reg >= warn:
            warned.append(f"WARN {line} (>= {warn:.0%})")
        else:
            ok.append(f"ok   {line}")
    return ok, warned, failed


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir", default=BASELINE_DIR)
    ap.add_argument("--fresh-dir", default=FRESH_DIR)
    ap.add_argument("--warn", type=float, default=0.10)
    ap.add_argument("--fail", type=float, default=0.25)
    ap.add_argument("--update", action="store_true",
                    help="copy fresh artifacts over the committed "
                         "baselines instead of gating")
    args = ap.parse_args()
    if not (0 <= args.warn <= args.fail):
        ap.error(f"need 0 <= --warn ({args.warn}) <= --fail ({args.fail})")

    if args.update:
        os.makedirs(args.baseline_dir, exist_ok=True)
        names = sorted(n for n in os.listdir(args.fresh_dir)
                       if n.startswith("BENCH_") and n.endswith(".json"))
        for name in names:
            shutil.copy(os.path.join(args.fresh_dir, name),
                        os.path.join(args.baseline_dir, name))
            print(f"baselined {name}")
        return 0

    if not os.path.isdir(args.baseline_dir):
        print(f"no baseline dir at {args.baseline_dir} — nothing gated "
              f"(run with --update after a bench pass to create it)")
        return 0
    baselines = sorted(n for n in os.listdir(args.baseline_dir)
                       if n.startswith("BENCH_") and n.endswith(".json"))
    fresh_names = (sorted(n for n in os.listdir(args.fresh_dir)
                          if n.startswith("BENCH_")
                          and n.endswith(".json"))
                   if os.path.isdir(args.fresh_dir) else [])
    any_failed = False
    for name in baselines:
        fresh_path = os.path.join(args.fresh_dir, name)
        if not os.path.exists(fresh_path):
            print(f"SKIP {name}: baseline committed but no fresh "
                  f"artifact — the bench that writes it did not run")
            continue
        base = json.load(open(os.path.join(args.baseline_dir, name)))
        fresh = json.load(open(fresh_path))
        ok, warned, failed = compare(base.get("results", base),
                                     fresh.get("results", fresh),
                                     warn=args.warn, fail=args.fail)
        print(f"== {name}: {len(ok)} ok, {len(warned)} warn, "
              f"{len(failed)} fail")
        for line in ok + warned + failed:
            print(f"   {line}")
        any_failed = any_failed or bool(failed)
    for name in fresh_names:
        if name not in baselines:
            print(f"note {name}: fresh artifact has no committed "
                  f"baseline — not gated")
    if any_failed:
        print(f"regression gate FAILED (fail bar {args.fail:.0%})")
        return 1
    print("regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
