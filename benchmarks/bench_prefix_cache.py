"""Shared-prefix radix cache vs cold chunked prefill (DESIGN.md
§Prefix cache).

Two claims, measured on the same engine weights:

  admission — latency of one chunked admission for a prompt whose
      first 75% is a shared system prefix, warm (longest-prefix-match
      restores the deepest chunk-boundary snapshot, only the unique
      suffix streams) vs cold (route + stream every chunk).  The hit
      path must issue NO prefill chunks for covered tokens — asserted
      structurally from the job counters, not timed.
  traffic — p50 TTFT under Poisson arrivals where every request opens
      with the same system prompt (the traffic shape the store exists
      for), continuous scheduler with the store vs without.  The
      acceptance bar is ≥2× p50 TTFT on the warm path.

Writes ``BENCH_prefix_cache.json``; ``--smoke`` shrinks shapes for CI.
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict, List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import numpy as np

from benchmarks.common import (CACHE_DIR, Row, bench_cfg, device_sync,
                               pct)
from repro.models import model as MD
from repro.serve import ContinuousScheduler, Request, ServeEngine


def _time_once(fn) -> float:
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(jax.tree.leaves(out))
    return time.perf_counter() - t0


def bench_admission(cfg, params, chunk: int, n_prefix_chunks: int = 3,
                    reps: int = 5) -> Dict:
    """Hit vs cold admission for prompts = shared prefix (75%) + unique
    suffix (25%, one chunk)."""
    rng = np.random.default_rng(0)
    prefix = rng.integers(0, cfg.vocab_size,
                          size=n_prefix_chunks * chunk).astype(np.int32)
    seq_len = (n_prefix_chunks + 1) * chunk
    max_len = seq_len + 64

    def prompt(i: int) -> np.ndarray:
        suffix = rng.integers(0, cfg.vocab_size, size=chunk
                              ).astype(np.int32)
        return np.concatenate([prefix, suffix])[None]

    cold = ServeEngine(params, cfg, max_len=max_len, prefill_chunk=chunk)
    warm = ServeEngine(params, cfg, max_len=max_len, prefill_chunk=chunk,
                       prefix_cache_mb=256, prefix_cache_host_mb=256)
    warm.prefill_chunked(prompt(0))  # publish the shared boundaries
    # compile both paths, then best-of-``reps`` interleaved (host CPU
    # throughput drifts between runs)
    cold.prefill_chunked(prompt(1))
    job = warm.prefill_chunked(prompt(2))
    assert job.prefix_hit_tokens == n_prefix_chunks * chunk
    # the structural claim: covered tokens issue no prefill chunks
    assert job.chunks_streamed == len(job.plan) - n_prefix_chunks
    t_cold = t_warm = float("inf")
    for i in range(reps):
        p = prompt(10 + i)
        t_cold = min(t_cold, _time_once(
            lambda: cold.prefill_chunked(p).caches))
        t_warm = min(t_warm, _time_once(
            lambda: warm.prefill_chunked(p).caches))
    warm._check_executable_guard()
    return {
        "seq_len": seq_len, "chunk": chunk,
        "prefix_tokens": n_prefix_chunks * chunk,
        "coverage": n_prefix_chunks / (n_prefix_chunks + 1),
        "cold_s": t_cold, "warm_s": t_warm,
        "speedup": t_cold / t_warm if t_warm else float("nan"),
        "hit_chunks_streamed": job.chunks_streamed,
        "cold_chunks_streamed": len(job.plan),
        "store": warm.prefix_store.stats().as_dict(),
    }


def bench_traffic(cfg, params, chunk: int, n_prefix_chunks: int = 3,
                  n_requests: int = 8) -> Dict:
    """p50 TTFT under shared-system-prompt Poisson traffic, with and
    without the prefix store (identical requests and arrivals)."""
    rng = np.random.default_rng(3)
    prefix = rng.integers(0, cfg.vocab_size,
                          size=n_prefix_chunks * chunk).astype(np.int32)
    # fresh suffixes per pass: the measured pass hits the warm prefix
    # (75% coverage) but never a full-cover repeat of a warmup prompt
    suffixes = [[rng.integers(0, cfg.vocab_size, size=chunk
                              ).astype(np.int32)
                 for _ in range(n_requests)] for _ in range(2)]
    arrivals = np.cumsum(rng.exponential(0.1, size=n_requests))
    max_len = (n_prefix_chunks + 1) * chunk + 64

    def drive(eng, pass_idx: int) -> Dict:
        sched = ContinuousScheduler(eng, slots_per_bucket=n_requests,
                                    chunk=4, prefill_chunks_per_tick=2)
        reqs = [Request(rid=i,
                        tokens=np.concatenate([prefix,
                                               suffixes[pass_idx][i]]),
                        n_steps=16) for i in range(n_requests)]
        pending = list(range(n_requests))
        done = {}
        t0 = time.perf_counter()
        while len(done) < n_requests:
            now = time.perf_counter() - t0
            while pending and arrivals[pending[0]] <= now:
                sched.submit(reqs[pending.pop(0)])
            if sched.n_active() or sched.waiting:
                for f in sched.tick():
                    done[f.rid] = f
            elif pending:
                time.sleep(min(max(arrivals[pending[0]] - now, 0.0),
                               0.005))
        device_sync()  # measurement boundary (common.py docstring)
        ttft = sorted(f.metrics.ttft for f in done.values())
        hit = sum(f.metrics.prefix_hit_tokens for f in done.values())
        prompt_toks = sum(f.metrics.prompt_len for f in done.values())
        return {
            "wall_s": time.perf_counter() - t0,
            "ttft_p50_s": pct(ttft, 50),
            "ttft_p95_s": pct(ttft, 95),
            "tokens_per_s": sum(f.metrics.n_generated
                                for f in done.values())
            / max(time.perf_counter() - t0, 1e-9),
            "prefill_chunk_ticks": sched.prefill_chunk_ticks,
            "prefix_hit_tokens": hit,
            "prefix_hit_fraction": hit / max(prompt_toks, 1),
        }

    out = {}
    for name, mb in (("cold", None), ("prefix_cache", 256)):
        eng = ServeEngine(params, cfg,
                          max_len=max_len, prefill_chunk=chunk,
                          prefix_cache_mb=mb,
                          prefix_cache_host_mb=mb or 0.0)
        drive(eng, 0)         # warm compile caches AND the prefix store
        out[name] = drive(eng, 1)
    out["ttft_p50_ratio"] = (out["cold"]["ttft_p50_s"]
                             / max(out["prefix_cache"]["ttft_p50_s"], 1e-9))
    out["admission_chunk_ratio"] = (
        out["cold"]["prefill_chunk_ticks"]
        / max(out["prefix_cache"]["prefill_chunk_ticks"], 1))
    return out


def run(chunk: int = 256, n_prefix_chunks: int = 3,
        n_requests: int = 8) -> List[Row]:
    cfg = bench_cfg()
    params = MD.init_params(jax.random.key(0), cfg)
    admission = bench_admission(cfg, params, chunk, n_prefix_chunks)
    traffic = bench_traffic(cfg, params, chunk, n_prefix_chunks,
                            n_requests)
    results = {"admission": admission, "traffic": traffic}
    os.makedirs(CACHE_DIR, exist_ok=True)
    with open(os.path.join(CACHE_DIR, "BENCH_prefix_cache.json"),
              "w") as f:
        json.dump({"timestamp": time.time(),
                   "device": jax.default_backend(),
                   "results": results}, f, indent=2)
    a, t = admission, traffic
    return [
        Row(f"prefix_cache/admission@{a['seq_len']}",
            a["warm_s"] * 1e6,
            f"speedup={a['speedup']:.2f}x;"
            f"coverage={a['coverage']:.2f};"
            f"chunks={a['hit_chunks_streamed']}/"
            f"{a['cold_chunks_streamed']}"),
        Row("prefix_cache/shared_prefix_traffic",
            t["prefix_cache"]["wall_s"] * 1e6,
            f"ttft_p50={t['prefix_cache']['ttft_p50_s'] * 1e3:.0f}ms;"
            f"ttft_p50_cold={t['cold']['ttft_p50_s'] * 1e3:.0f}ms;"
            f"ratio={t['ttft_p50_ratio']:.2f}x;"
            f"hit_frac={t['prefix_cache']['prefix_hit_fraction']:.2f};"
            f"chunk_ratio={t['admission_chunk_ratio']:.2f}x"),
    ]


def main() -> None:
    smoke = "--smoke" in sys.argv
    rows = run(chunk=32, n_requests=6) if smoke else run()
    for r in rows:
        print(r.csv())
    data = json.load(open(os.path.join(CACHE_DIR,
                                       "BENCH_prefix_cache.json")))
    res = data["results"]
    ok = True
    a = res["admission"]
    # structural claim, non-negotiable at any scale: covered tokens
    # issue no prefill chunks on the hit path
    covered_chunks = a["cold_chunks_streamed"] - a["hit_chunks_streamed"]
    if covered_chunks * a["chunk"] != a["prefix_tokens"]:
        print("# FAIL hit path streamed chunks for covered tokens")
        ok = False
    ratio = res["traffic"]["ttft_p50_ratio"]
    if ratio < 2.0:
        msg = (f"# {'WARN' if smoke else 'FAIL'} shared-prefix TTFT "
               f"p50 ratio {ratio:.2f}x < 2.0x"
               + (" (smoke shapes — advisory)" if smoke else ""))
        print(msg)
        ok = ok if smoke else False
    if not ok:
        sys.exit(1)
    print(f"# ok prefix cache: admission {a['speedup']:.2f}x, "
          f"traffic ttft p50 {ratio:.2f}x, covered tokens issue no "
          f"prefill chunks")


if __name__ == "__main__":
    main()
