"""Paper Fig. 3(a): end-to-end prefill speedup vs context length.

Measured CPU wall-clock of the jitted prefill under flux fixed Ω=0.5
(FA-SSA and FA-TA) vs dense, plus the derived FLOP-model speedup at
the paper's 256K point (mode_flops)."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, time_call, trained_model
from repro.core import modes as M
from repro.models import model as MD

LENGTHS = [128, 256, 512, 1024]


def run() -> List[Row]:
    cfg, params = trained_model()
    rng = np.random.default_rng(0)
    rows: List[Row] = []
    half = np.array([i % 2 for i in range(cfg.num_layers)], np.int64)

    variants = {
        "dense": dict(routing_ctx="fa_only"),
        "flux-FA-SSA-0.5": dict(routing_ctx="fixed",
                                fixed_pattern=jnp.asarray(half)),
        "flux-FA-TA-0.5": dict(routing_ctx="fixed",
                               fixed_pattern=jnp.asarray(half),
                               sa_mode="ta"),
    }
    base_us = {}
    for name, kw in variants.items():
        cfg_v = cfg
        if kw.pop("sa_mode", None) == "ta":
            cfg_v = cfg.replace(flux=cfg.flux.replace(sa_mode="ta",
                                                      chunk=64))
        per_len = []
        for S in LENGTHS:
            toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, S)),
                               jnp.int32)
            fn = jax.jit(lambda t, kw=dict(kw), c=cfg_v: MD.prefill(
                params, c, t, want_cache=False, **kw).logits)
            us = time_call(fn, toks, warmup=1, iters=3)
            per_len.append(us)
            base_us.setdefault(S, us if name == "dense" else None)
        sp = [base_us[S] / u if base_us[S] else float("nan")
              for S, u in zip(LENGTHS, per_len)]
        derived = " ".join(f"S{S}={s:.2f}x"
                           for S, s in zip(LENGTHS, sp))
        rows.append(Row(f"prefill_speedup/{name}", per_len[-1], derived))

    # derived 256K FLOP-model speedup (paper's operating point)
    S = 262144
    H, D = cfg.num_heads, cfg.head_dim
    fa = M.mode_flops(M.FULL, S, S, H, D)
    flux = cfg.flux.replace(sink=128, local=2048, chunk=16384)
    ssa = M.mode_flops(M.ssa_mode(flux), S, S, H, D)
    ta = M.mode_flops(M.ta_mode(flux), S, S, H, D)
    for nm, sa in (("ssa", ssa), ("ta", ta)):
        mix = 0.5 * fa + 0.5 * sa  # Ω=0.5 layer mix
        rows.append(Row(f"prefill_speedup/derived256k_{nm}", 0.0,
                        f"attn_flop_speedup={fa / mix:.2f}x"))
    return rows
