"""Paper Fig. 1(a): task accuracy vs Ω_MSR under UnComp entropy-ranked
progressive layer sparsification — retrieval collapses past a
threshold, holistic stays flat."""
from __future__ import annotations

from typing import List

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, eval_accuracy, trained_model
from repro.core import policies
from repro.data import SyntheticTasks

MSRS = [0.0, 0.25, 0.5, 0.75, 1.0]


def run() -> List[Row]:
    cfg, params = trained_model()
    gen = SyntheticTasks(cfg.vocab_size, seed=0)
    probe = gen.batch(np.random.default_rng(1), "needle", 8, 96)
    scores = policies.entropy_scores(params, cfg,
                                     jnp.asarray(probe.tokens))
    rows: List[Row] = []
    for task in ("needle", "markov"):
        accs = []
        for msr in MSRS:
            pat = policies.entropy_pattern(cfg, scores, msr)
            accs.append(eval_accuracy(cfg, params, task, pattern=pat,
                                      needle_pos=0.3))
        derived = " ".join(f"msr{m:.2f}={a:.3f}"
                           for m, a in zip(MSRS, accs))
        rows.append(Row(f"sparsity_sweep/{task}", 0.0, derived))
    return rows
