"""Graceful degradation under overload (DESIGN.md §Robustness & SLO).

An overload burst — every request arrives at once into a small slot
pool — served twice by the continuous scheduler:

  guardrails OFF — unbounded queue, no deadlines, neutral routing: the
      classic cliff.  Everything is eventually served, but tail TTFT
      grows with queue depth and most requests blow any latency target.
  guardrails ON  — bounded queue (shed), per-request deadlines
      (timeout), and the load-adaptive sparsity dial: the scheduler
      sacrifices hopeless work explicitly so surviving requests meet
      the target.

Reports p50/p99 TTFT over the requests that actually served, *goodput*
(tokens from ``ok``-status requests that finished within the SLO
target, per second of wall clock), and per-status counts.  A second
sweep serves increasing burst sizes with guardrails on and records the
mean SA fraction of admitted requests — the quality-vs-load curve of
the sparsity dial (quality degrades monotonically with pressure
instead of latency collapsing).

Writes ``BENCH_degraded.json``.
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import numpy as np

from benchmarks.common import CACHE_DIR, Row, bench_cfg, device_sync, pct
from repro.models import model as MD
from repro.serve import (ContinuousScheduler, Request, SLOConfig,
                         STATUS_OK, ServeEngine, STATUSES)

LENS = (24, 32, 40, 48)


def _requests(cfg, n: int, n_steps: int, rid0: int = 0,
              seed: int = 0) -> List[Request]:
    rng = np.random.default_rng(seed)
    return [Request(rid=rid0 + i,
                    tokens=rng.integers(0, cfg.vocab_size,
                                        size=LENS[i % len(LENS)]
                                        ).astype(np.int32),
                    n_steps=n_steps)
            for i in range(n)]


def _sa_fraction(routing) -> float:
    routed = [p for p in (routing or ()) if p is not None]
    return (sum(p == "sa" for p in routed) / len(routed)
            if routed else float("nan"))


def _run_burst(eng: ServeEngine, reqs: List[Request], *,
               slots: int, chunk: int, slo_target_s: float) -> Dict:
    """Submit every request at t=0, tick until all work retired.
    A fresh scheduler per burst (the engine's jit caches stay warm);
    the engine's own SLOConfig governs the guardrails."""
    sched = ContinuousScheduler(eng, slots_per_bucket=slots, chunk=chunk,
                                prefill_chunks_per_tick=4, slo=eng.slo)
    t0 = time.perf_counter()
    for r in reqs:
        sched.submit(r)
    done = {}
    while sched.waiting or sched.n_active():
        for f in sched.tick():
            done[f.rid] = f
    for f in sched.tick():  # announce any submit-time sheds
        done[f.rid] = f
    device_sync()  # measurement boundary (common.py docstring)
    wall = time.perf_counter() - t0
    ttft = [f.metrics.ttft for f in done.values()]
    status_counts = {s: sum(f.status == s for f in done.values())
                     for s in STATUSES}
    good_tokens = sum(
        f.metrics.n_generated for f in done.values()
        if f.status == STATUS_OK
        and f.metrics.finish_t - f.metrics.arrival_t <= slo_target_s)
    tokens = sum(f.metrics.n_generated for f in done.values())
    sa = [_sa_fraction(f.routing) for f in done.values()
          if f.routing is not None]
    return {
        "n_requests": len(reqs), "wall_s": wall, "tokens": tokens,
        "goodput_tokens_per_sec": good_tokens / wall,
        "tokens_per_sec": tokens / wall,
        "ttft_p50_s": pct(ttft, 50), "ttft_p99_s": pct(ttft, 99),
        "status_counts": status_counts,
        "served_fraction": (status_counts[STATUS_OK] / len(reqs)
                            if reqs else 0.0),
        "mean_sa_fraction": (float(np.nanmean(sa)) if sa
                             else float("nan")),
        "sa_level_final": eng.sa_level,
        "geometries": sched.n_geometries(),
        "decode_executables": eng.decode_cache_size(),
    }


def _engine(params, cfg, max_len: int,
            slo: Optional[SLOConfig]) -> ServeEngine:
    return ServeEngine(params, cfg, max_len=max_len, slo=slo)


def run(n_requests: int = 24, n_steps: int = 64, slots: int = 4,
        chunk: int = 8, slo_target_s: float = 2.0,
        loads: tuple = (8, 16, 24)) -> List[Row]:
    cfg = bench_cfg()
    params = MD.init_params(jax.random.key(0), cfg)
    max_len = max(LENS) + n_steps + 2
    guarded = SLOConfig(max_queue=max(2 * slots, 2),
                        default_deadline_s=slo_target_s,
                        adaptive_sparsity=True, pressure_patience=1)

    # separate engines (separate jit caches, separate schedulers); one
    # warmup burst each keeps compile time out of the measured run
    eng_off = _engine(params, cfg, max_len, None)
    eng_on = _engine(params, cfg, max_len, guarded)
    _run_burst(eng_off, _requests(cfg, n_requests, n_steps),
               slots=slots, chunk=chunk, slo_target_s=slo_target_s)
    _run_burst(eng_on, _requests(cfg, n_requests, n_steps),
               slots=slots, chunk=chunk, slo_target_s=slo_target_s)
    off = _run_burst(eng_off,
                     _requests(cfg, n_requests, n_steps, rid0=1000),
                     slots=slots, chunk=chunk, slo_target_s=slo_target_s)
    on = _run_burst(eng_on,
                    _requests(cfg, n_requests, n_steps, rid0=1000),
                    slots=slots, chunk=chunk, slo_target_s=slo_target_s)

    # quality-vs-load: guardrails on, rising burst size — the dial
    # should trade SA fraction (quality) for admission, monotonically
    # in pressure, while TTFT of the served set stays bounded
    curve = []
    for li, load in enumerate(loads):
        eng = _engine(params, cfg, max_len, guarded)
        r = _run_burst(eng, _requests(cfg, load, n_steps, seed=2 + li),
                       slots=slots, chunk=chunk,
                       slo_target_s=slo_target_s)
        curve.append({"offered_load": load,
                      "mean_sa_fraction": r["mean_sa_fraction"],
                      "ttft_p50_s": r["ttft_p50_s"],
                      "served_fraction": r["served_fraction"],
                      "shed": r["status_counts"]["shed"],
                      "timeout": r["status_counts"]["timeout"],
                      "sa_level_final": r["sa_level_final"]})

    results = {
        "n_requests": n_requests, "n_steps": n_steps,
        "slots_per_bucket": slots, "chunk": chunk,
        "slo_target_s": slo_target_s,
        "guardrails_off": off, "guardrails_on": on,
        "quality_vs_load": curve,
    }
    os.makedirs(CACHE_DIR, exist_ok=True)
    with open(os.path.join(CACHE_DIR, "BENCH_degraded.json"), "w") as f:
        json.dump({"timestamp": time.time(),
                   "device": jax.default_backend(),
                   "results": results}, f, indent=2)

    def fmt(r: Dict) -> str:
        sc = r["status_counts"]
        return (f"ttft_p50={r['ttft_p50_s'] * 1e3:.0f}ms;"
                f"ttft_p99={r['ttft_p99_s'] * 1e3:.0f}ms;"
                f"goodput={r['goodput_tokens_per_sec']:.0f}tok/s;"
                f"ok={sc['ok']};shed={sc['shed']};"
                f"timeout={sc['timeout']}")

    return [
        Row("degraded-mode/guardrails-off", off["wall_s"] * 1e6, fmt(off)),
        Row("degraded-mode/guardrails-on", on["wall_s"] * 1e6, fmt(on)),
        Row("degraded-mode/quality-vs-load", 0.0,
            ";".join(f"load{c['offered_load']}:"
                     f"sa={c['mean_sa_fraction']:.2f}"
                     f"@{c['ttft_p50_s'] * 1e3:.0f}ms"
                     for c in curve)),
    ]


def main() -> None:
    smoke = "--smoke" in sys.argv
    rows = (run(n_requests=6, n_steps=8, slots=2, slo_target_s=30.0,
                loads=(3, 6))
            if smoke else run())
    for r in rows:
        print(r.csv())
    data = json.load(open(os.path.join(CACHE_DIR, "BENCH_degraded.json")))
    res = data["results"]
    off, on = res["guardrails_off"], res["guardrails_on"]
    # advisory on shared/smoke runners: guarded tail TTFT (over the
    # served set) should not exceed the unguarded tail
    if (np.isfinite(on["ttft_p99_s"]) and np.isfinite(off["ttft_p99_s"])
            and on["ttft_p99_s"] > off["ttft_p99_s"]):
        print("# WARN guardrails-on p99 TTFT exceeds guardrails-off"
              + (" (smoke shapes — advisory)" if smoke else ""))
    else:
        print(f"# ok degraded-mode: p99 {on['ttft_p99_s']:.3f}s (on) vs "
              f"{off['ttft_p99_s']:.3f}s (off), goodput "
              f"{on['goodput_tokens_per_sec']:.0f} vs "
              f"{off['goodput_tokens_per_sec']:.0f} tok/s")


if __name__ == "__main__":
    main()
