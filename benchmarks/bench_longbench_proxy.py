"""Paper Table 1 (LongBench-E proxy): accuracy + Ω_MSR per task for
flux vs static baselines on the synthetic retrieval/holistic suites."""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import Row, eval_accuracy, live_msr, trained_model
from repro.core import policies

TASKS = ["needle", "multihop", "markov"]


def run() -> List[Row]:
    cfg, params = trained_model()
    rows: List[Row] = []
    n_layers = cfg.num_layers

    methods = {
        "backbone-FA": dict(routing_ctx="fa_only"),
        "flux-hard": dict(routing_ctx="hard"),
        "trianglemix-0.5": dict(
            pattern=policies.trianglemix_pattern(cfg, 0.5)),
        "static-shallow-0.5": dict(
            pattern=policies.static_pattern(cfg, 0.5, "shallow")),
        "duo-headsplit-0.5": dict(routing_ctx="head_split",
                                  head_split_n=max(
                                      1, cfg.num_kv_heads // 2)),
        "all-SA": dict(pattern=np.zeros(n_layers, np.int64)),
    }
    for name, kw in methods.items():
        accs = {}
        for task in TASKS:
            accs[task] = eval_accuracy(cfg, params, task, **kw)
        if name == "flux-hard":
            msr = np.nanmean([live_msr(cfg, params, t) for t in TASKS])
        elif "pattern" in kw:
            msr = float(1.0 - np.asarray(kw["pattern"]).mean())
        elif name == "duo-headsplit-0.5":
            msr = 0.5
        else:
            msr = 0.0
        avg = np.mean(list(accs.values()))
        derived = (f"acc_avg={avg:.3f} msr={msr:.2f} "
                   + " ".join(f"{t}={a:.3f}" for t, a in accs.items()))
        rows.append(Row(f"longbench_proxy/{name}", 0.0, derived))
    return rows
