"""Continuous batching vs batch-synchronous serving (DESIGN.md §Scheduler).

Mixed-length Poisson traffic against the two frontends of the same
engine:

  serve_batch — buckets requests by exact (length, n_steps), waits for
      the full arrival window, runs each bucket to completion.  A
      request's first token only exists when its whole bucket's fused
      decode scan returns.
  ContinuousScheduler — slot-pool decode; requests join a persistent
      batch at the next tick after arrival and stream out per chunk.

Reports token throughput (busy tok/s) and p50/p95 TTFT for both, and
writes ``BENCH_serving.json`` for the perf trajectory.  The acceptance
bar for this subsystem is ≥1.5× throughput on the mixed-length
workload (continuous batching merges the per-length buckets into one
resident decode batch, amortizing per-step dispatch across requests).
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict, List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import numpy as np

from benchmarks.common import (CACHE_DIR, Row, bench_cfg, device_sync,
                               mixed_pattern, pct)
from repro.models import model as MD
from repro.serve import ContinuousScheduler, Request, ServeEngine

# all-distinct prompt lengths: the mixed-traffic shape the subsystem
# exists for — real traffic rarely collides on exact length, so
# exact-length bucketing degenerates to B=1 buckets that serialize,
# while the slot pool still decodes everything as one batch
LENS = tuple(range(24, 88, 4))  # 16 unique lengths


def _requests(cfg, n: int, n_steps: int, seed: int = 0) -> List[Request]:
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab_size,
                                        size=LENS[i % len(LENS)]
                                        ).astype(np.int32),
                    n_steps=n_steps)
            for i in range(n)]


def _arrivals(n: int, mean_gap_s: float, seed: int = 1) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(mean_gap_s, size=n))


def _run_batch(eng: ServeEngine, reqs: List[Request],
               arrivals: np.ndarray) -> Dict:
    """serve_batch semantics with per-bucket timing: serving starts once
    every request has arrived; a request's TTFT is its bucket's
    completion (the fused scan yields no earlier tokens)."""
    buckets: Dict[tuple, List[int]] = {}
    for i, r in enumerate(reqs):
        buckets.setdefault((len(r.tokens), r.n_steps), []).append(i)
    t = float(arrivals.max())  # batch frontend waits for stragglers
    busy = 0.0
    ttft, tokens = [], 0
    for (_, n_steps), idxs in buckets.items():
        toks = np.stack([reqs[i].tokens for i in idxs])
        t0 = time.perf_counter()
        gen = eng.generate(toks, n_steps)  # tokens land on host here
        dt = time.perf_counter() - t0
        busy += dt
        t += dt
        tokens += gen.tokens.size
        ttft.extend(t - arrivals[i] for i in idxs)
    return {"tokens": tokens, "busy_s": busy,
            "tokens_per_sec": tokens / busy,
            "ttft_p50_s": pct(ttft, 50), "ttft_p95_s": pct(ttft, 95)}


def _run_continuous(eng: ServeEngine, reqs: List[Request],
                    arrivals: np.ndarray, *, slots: int,
                    chunk: int) -> Dict:
    """Submit on the (wall-clock) Poisson schedule, tick until drained."""
    sched = ContinuousScheduler(eng, slots_per_bucket=slots, chunk=chunk)
    t0 = time.perf_counter()
    pending = sorted(range(len(reqs)), key=lambda i: arrivals[i])
    submitted_at = {}
    done = {}
    while len(done) < len(reqs):
        now = time.perf_counter() - t0
        while pending and arrivals[pending[0]] <= now:
            i = pending.pop(0)
            sched.submit(reqs[i])
            submitted_at[reqs[i].rid] = now
        if sched.n_active() or sched.waiting:
            for f in sched.tick():
                done[f.rid] = f
        elif pending:  # idle until the next Poisson arrival
            time.sleep(min(max(arrivals[pending[0]] - now, 0.0), 0.005))
    # measurement boundary (common.py docstring): every tick already
    # synced on np.asarray(toks), but close the interval on an explicit
    # barrier so in-flight device work cannot leak past the stop-clock
    device_sync()
    busy = time.perf_counter() - t0
    tokens = sum(f.metrics.n_generated for f in done.values())
    ttft = [f.metrics.ttft for f in done.values()]
    qd = [f.metrics.queue_delay for f in done.values()]
    return {"tokens": tokens, "busy_s": busy,
            "tokens_per_sec": tokens / busy,
            "ttft_p50_s": pct(ttft, 50), "ttft_p95_s": pct(ttft, 95),
            "queue_delay_p50_s": pct(qd, 50),
            "geometries": sched.n_geometries(),
            "decode_executables": eng.decode_cache_size(),
            "ticks": sched.ticks}


def run(n_requests: int = 16, n_steps: int = 128, slots: int = 16,
        chunk: int = 8, mean_gap_s: float = 0.005) -> List[Row]:
    cfg = bench_cfg()
    params = MD.init_params(jax.random.key(0), cfg)
    reqs = _requests(cfg, n_requests, n_steps)
    arrivals = _arrivals(n_requests, mean_gap_s)
    max_len = max(LENS) + n_steps + 2

    # pin one realistic FA/SA mix on both paths: the bench isolates the
    # *scheduling* transformation (bucketed run-to-completion vs slot
    # pool); an untrained router would scatter requests over arbitrary
    # geometries and measure router noise instead.  Multi-geometry
    # admission is covered by tests/test_continuous_batching.py.
    pattern = mixed_pattern(cfg)
    # separate engines (separate jit caches) — warm each path once on
    # the full workload so compile time stays out of the timings, then
    # keep the best of ``reps`` interleaved measurements per path (the
    # host's available CPU throughput drifts by integer factors between
    # runs; min-time is the standard estimator under such contamination)
    reps = 3
    eng_b = ServeEngine(params, cfg, max_len=max_len,
                        routing_override=pattern)
    eng_c = ServeEngine(params, cfg, max_len=max_len,
                        routing_override=pattern)
    _run_batch(eng_b, reqs, arrivals)
    _run_continuous(eng_c, reqs, arrivals, slots=slots, chunk=chunk)
    batch = cont = None
    for _ in range(reps):
        b = _run_batch(eng_b, reqs, arrivals)
        c = _run_continuous(eng_c, reqs, arrivals, slots=slots,
                            chunk=chunk)
        if batch is None or b["tokens_per_sec"] > batch["tokens_per_sec"]:
            batch = b
        if cont is None or c["tokens_per_sec"] > cont["tokens_per_sec"]:
            cont = c

    speedup = cont["tokens_per_sec"] / batch["tokens_per_sec"]

    # telemetry overhead leg: the same continuous workload with the
    # metrics registry + span tracer + flight recorder enabled.  The
    # acceptance bar (ISSUE 7 / DESIGN.md §Observability) is zero extra
    # compiled executables and ≤5% tok/s overhead.
    eng_t = ServeEngine(params, cfg, max_len=max_len,
                        routing_override=pattern, telemetry=True)
    _run_continuous(eng_t, reqs, arrivals, slots=slots, chunk=chunk)
    # attribution leg: telemetry PLUS the ISSUE 9 cost-attribution
    # layer at its default cadences — sampled tick profiler (sync
    # boundaries only on every 32nd tick), fidelity probes on every
    # 16th admission, and the per-tick memory ledger.  Same ≤5% bar.
    eng_a = ServeEngine(params, cfg, max_len=max_len,
                        routing_override=pattern, telemetry=True,
                        profile_every=32, fidelity_probe_every=16,
                        memory_ledger=True)
    _run_continuous(eng_a, reqs, arrivals, slots=slots, chunk=chunk)
    # overhead is measured with every request submitted up front: the
    # off and on runs then execute the *identical* tick/batch sequence
    # (the telemetry parity test proves bitwise-equal tokens), so the
    # ratio isolates instrumentation cost instead of folding in the
    # Poisson arrival/tick-phase coupling of the wall-clock workload.
    # Legs rotate order within each rep so host drift cancels too.
    now_arrivals = np.zeros_like(arrivals)
    best = {"ref": None, "tele": None, "attr": None}
    legs = [(eng_c, "ref"), (eng_t, "tele"), (eng_a, "attr")]
    for r in range(2 * reps):
        for eng, label in legs[r % 3:] + legs[:r % 3]:
            m = _run_continuous(eng, reqs, now_arrivals, slots=slots,
                                chunk=chunk)
            if (best[label] is None
                    or m["tokens_per_sec"] > best[label]["tokens_per_sec"]):
                best[label] = m
    ref, tele, attr = best["ref"], best["tele"], best["attr"]
    overhead = max(0.0, 1.0 - tele["tokens_per_sec"]
                   / ref["tokens_per_sec"])
    attr_overhead = max(0.0, 1.0 - attr["tokens_per_sec"]
                        / ref["tokens_per_sec"])
    extra_execs = (eng_t.decode_cache_size() - eng_c.decode_cache_size())
    attr_extra_execs = (eng_a.decode_cache_size()
                        - eng_c.decode_cache_size())
    # the profiler/ledger report artifact CI uploads: the attribution
    # engine's full JSON-ready report, reconciliation deltas included
    attr_report = eng_a.attribution_report()
    # probes performed = admissions the every-Nth gate sampled (first
    # admission always probes)
    n_adm = attr_report["probe_admissions"]
    every = attr_report["fidelity_probe_every"]
    n_probed = 0 if not n_adm else (n_adm - 1) // every + 1

    results = {
        "n_requests": n_requests, "n_steps": n_steps,
        "prompt_lens": list(LENS), "slots_per_bucket": slots,
        "chunk": chunk, "mean_arrival_gap_s": mean_gap_s,
        "serve_batch": batch, "continuous": cont,
        "throughput_speedup": speedup,
        "continuous_telemetry": tele,
        "telemetry_overhead_frac": overhead,
        "telemetry_extra_executables": extra_execs,
        "continuous_attribution": attr,
        "attribution_overhead_frac": attr_overhead,
        "attribution_extra_decode_executables": attr_extra_execs,
        "attribution_report": attr_report,
    }
    os.makedirs(CACHE_DIR, exist_ok=True)
    with open(os.path.join(CACHE_DIR, "BENCH_serving.json"), "w") as f:
        json.dump({"timestamp": time.time(),
                   "device": jax.default_backend(),
                   "results": results}, f, indent=2)
    rows = [
        Row("continuous-batching/serve_batch", batch["busy_s"] * 1e6,
            f"tps={batch['tokens_per_sec']:.0f};"
            f"ttft_p50={batch['ttft_p50_s'] * 1e3:.0f}ms;"
            f"ttft_p95={batch['ttft_p95_s'] * 1e3:.0f}ms"),
        Row("continuous-batching/slot-pool", cont["busy_s"] * 1e6,
            f"tps={cont['tokens_per_sec']:.0f};"
            f"ttft_p50={cont['ttft_p50_s'] * 1e3:.0f}ms;"
            f"ttft_p95={cont['ttft_p95_s'] * 1e3:.0f}ms;"
            f"speedup={speedup:.2f}x;"
            f"geoms={cont['geometries']};"
            f"execs={cont['decode_executables']}"),
        Row("continuous-batching/telemetry-on", tele["busy_s"] * 1e6,
            f"tps={tele['tokens_per_sec']:.0f};"
            f"overhead={overhead:.1%};"
            f"extra_execs={extra_execs}"),
        Row("continuous-batching/attribution-on", attr["busy_s"] * 1e6,
            f"tps={attr['tokens_per_sec']:.0f};"
            f"overhead={attr_overhead:.1%};"
            f"extra_decode_execs={attr_extra_execs};"
            f"probed={n_probed}/{attr_report['probe_admissions']}"),
    ]
    return rows


def main() -> None:
    smoke = "--smoke" in sys.argv
    rows = (run(n_requests=6, n_steps=8, slots=4, chunk=4)
            if smoke else run())
    for r in rows:
        print(r.csv())
    data = json.load(open(os.path.join(CACHE_DIR, "BENCH_serving.json")))
    speedup = data["results"]["throughput_speedup"]
    # correctness is gated in tests; the throughput ratio is advisory on
    # shared/smoke runners but the full run should clear 1.5×
    if speedup < 1.5:
        print(f"# WARN continuous-batching speedup {speedup:.2f}x < 1.5x"
              + (" (smoke shapes — advisory)" if smoke else ""))
    else:
        print(f"# ok continuous-batching speedup {speedup:.2f}x")
    overhead = data["results"]["telemetry_overhead_frac"]
    extra = data["results"]["telemetry_extra_executables"]
    if extra:
        print(f"# WARN telemetry added {extra} compiled executables "
              f"(must be 0)")
    if overhead > 0.05:
        print(f"# WARN telemetry overhead {overhead:.1%} > 5%"
              + (" (smoke shapes — advisory)" if smoke else ""))
    else:
        print(f"# ok telemetry overhead {overhead:.1%} "
              f"(extra executables: {extra})")
    attr_overhead = data["results"]["attribution_overhead_frac"]
    recon = data["results"]["attribution_report"]["ledger"][
        "reconciliation"]
    if attr_overhead > 0.05:
        print(f"# WARN attribution overhead {attr_overhead:.1%} > 5%"
              + (" (smoke shapes — advisory)" if smoke else ""))
    else:
        print(f"# ok attribution overhead {attr_overhead:.1%}")
    if recon["payload_delta"] or recon["prefix_device_delta"]:
        print(f"# WARN ledger reconciliation not exact: {recon}")
    else:
        print(f"# ok ledger reconciles (payload_delta=0)")


if __name__ == "__main__":
    main()
