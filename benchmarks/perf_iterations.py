"""§Perf hillclimb driver: runs the three chosen (arch × shape) pairs
through their optimization variants (each a subprocess of
repro.launch.dryrun so the 512-device env stays isolated) and emits the
before/after table for EXPERIMENTS.md.

Targets (chosen from the baseline roofline table):
  1. phi3-mini-3.8b × decode_32k — most representative of the paper's
     decode claim; baseline is collective-bound on *weight* gathers.
     Iterations: +decode-tp (row/column TP), then Ω_MSR ablation
     (0 → 0.5 → 1) quantifying the paper's technique on the memory
     term.
  2. command-r-plus-104b × long_500k — sequence-sharded KV; iteration:
     shard_map LSE-combine decode (+tp).
  3. deepseek-v2-236b × prefill_32k — compute-bound (masked-rectangle
     causal waste); iteration: recursive causal split depth 1..3.
  plus command-r train_4k seq-shard ablation (most collective-bound
  train step).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, List, Optional

ROOT = os.path.join(os.path.dirname(__file__), "..")
OUT = os.path.join(ROOT, "artifacts", "perf")

ITERATIONS = [
    # (arch, shape, extra flags, label)
    ("phi3-mini-3.8b", "decode_32k", [], "baseline"),
    ("phi3-mini-3.8b", "decode_32k", ["--decode-tp"], "decode-tp"),
    ("phi3-mini-3.8b", "decode_32k",
     ["--decode-tp", "--decode-msr", "0.0"], "decode-tp+allFA"),
    ("phi3-mini-3.8b", "decode_32k",
     ["--decode-tp", "--decode-msr", "1.0"], "decode-tp+allSA"),
    ("command-r-plus-104b", "long_500k", [], "baseline"),
    ("command-r-plus-104b", "long_500k", ["--decode-tp"], "decode-tp"),
    ("command-r-plus-104b", "long_500k",
     ["--decode-tp", "--distributed-kv"], "decode-tp+distkv"),
    ("deepseek-v2-236b", "prefill_32k", [], "baseline"),
    ("deepseek-v2-236b", "prefill_32k", ["--causal-split", "1"],
     "causal-split-1"),
    ("deepseek-v2-236b", "prefill_32k", ["--causal-split", "3"],
     "causal-split-3"),
    ("command-r-plus-104b", "train_4k", [], "baseline"),
    ("command-r-plus-104b", "train_4k", ["--no-seq-shard"],
     "no-seq-shard"),
]


def run_variant(arch: str, shape: str, flags: List[str],
                label: str) -> Optional[Dict]:
    env = dict(os.environ,
               PYTHONPATH=os.path.join(ROOT, "src"))
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", OUT] + flags
    print(f"--- {arch} × {shape} [{label}] ---", flush=True)
    r = subprocess.run(cmd, env=env, cwd=ROOT, capture_output=True,
                       text=True, timeout=3600)
    print(r.stdout.strip().splitlines()[-2:] if r.stdout else r.stderr[-300:])
    # locate the record (variant suffix included in mesh name)
    recs = []
    for f in os.listdir(OUT):
        if f.startswith(f"{arch}_{shape}_") and f.endswith(".json"):
            with open(os.path.join(OUT, f)) as fh:
                recs.append((os.path.getmtime(os.path.join(OUT, f)),
                             json.load(fh)))
    recs.sort()
    return recs[-1][1] if recs else None


def main() -> None:
    os.makedirs(OUT, exist_ok=True)
    rows = ["arch,shape,variant,ok,t_compute_s,t_memory_s,"
            "t_collective_s,bottleneck,hbm_bytes,coll_bytes"]
    for arch, shape, flags, label in ITERATIONS:
        rec = run_variant(arch, shape, flags, label)
        if rec is None or not rec.get("ok"):
            rows.append(f"{arch},{shape},{label},FAIL,,,,,,")
            continue
        rl = rec["roofline"]
        rows.append(
            f"{arch},{shape},{label},OK,{rl['t_compute_s']:.3e},"
            f"{rl['t_memory_s']:.3e},{rl['t_collective_s']:.3e},"
            f"{rl['bottleneck']},{rl['hbm_traffic_bytes_per_chip']},"
            f"{rl['collective_bytes_per_chip']:.3e}")
        print(rows[-1], flush=True)
    with open(os.path.join(OUT, "perf_iterations.csv"), "w") as f:
        f.write("\n".join(rows) + "\n")
    print("\n".join(rows))


if __name__ == "__main__":
    main()
