"""Paper Fig. 6 / §5.3: continued backbone training with a FROZEN
Layer Router — the backbone adapts its representations to the fixed
sparse pathways and recovers/improves performance."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, eval_accuracy, trained_model
from repro.data import mixture_iterator
from repro.train import ContinuedTrainer
from repro.train.train_loop import chunked_cross_entropy


def run() -> List[Row]:
    cfg, params = trained_model()
    it = mixture_iterator(cfg.vocab_size, 16, 96, seed=5,
                          weights={"markov": 0.5, "needle": 0.5})
    ct = ContinuedTrainer(cfg, total_steps=120, lr=5e-4)
    state = ct.init(params)
    key = jax.random.key(11)
    accs = {0: eval_accuracy(cfg, ct.params(state), "needle",
                             routing_ctx="hard")}
    losses = []
    for i in range(120):
        b = next(it)
        key, sub = jax.random.split(key)
        state, m = ct.step(state, jnp.asarray(b.tokens),
                           jnp.asarray(b.labels),
                           jnp.asarray(b.loss_mask), sub)
        losses.append(float(m["ce"]))
        if i + 1 in (50, 120):
            accs[i + 1] = eval_accuracy(cfg, ct.params(state), "needle",
                                        routing_ctx="hard")
    trend = "improving" if np.mean(losses[-20:]) < np.mean(losses[:20]) \
        else "flat"
    derived = (" ".join(f"step{k}={v:.3f}" for k, v in accs.items())
               + f" ce_first20={np.mean(losses[:20]):.3f}"
               + f" ce_last20={np.mean(losses[-20:]):.3f} ({trend})")
    return [Row("continued_training/frozen-router", 0.0, derived)]
