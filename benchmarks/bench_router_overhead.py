"""Paper Fig. 9: router overhead vs sequence length (512 → 1M).

The prefix-suffix pooling reads only the boundary tokens, so the
router's cost must be length-invariant."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, time_call, trained_model
from repro.core import router as R

LENGTHS = [512, 8192, 131072, 1048576]


def run() -> List[Row]:
    cfg, params = trained_model()
    in_dim = cfg.num_heads * cfg.head_dim
    rp = R.router_init(jax.random.key(0), in_dim, cfg.flux)
    rows: List[Row] = []
    us_all = []
    fn = jax.jit(lambda x: R.router_logits(rp, x, cfg.flux.pool_size))
    for S in LENGTHS:
        x = jnp.zeros((1, S, in_dim), jnp.bfloat16)
        us = time_call(fn, x, warmup=1, iters=3)
        us_all.append(us)
        rows.append(Row(f"router_overhead/S{S}", us,
                        f"pool={cfg.flux.pool_size}"))
    ratio = max(us_all) / max(min(us_all), 1e-9)
    rows.append(Row("router_overhead/length_invariance", 0.0,
                    f"max_over_min={ratio:.2f} (≈1 ⇒ invariant)"))
    return rows
