# One function per paper table. Print ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import (bench_continued_training,  # noqa: E402
                        bench_continuous_batching, bench_data_balance,
                        bench_decode_speedup, bench_degraded_mode,
                        bench_head_vs_layer, bench_longbench_proxy,
                        bench_prefill_speedup, bench_prefix_cache,
                        bench_router_overhead, bench_ruler_proxy,
                        bench_sparsity_sweep, bench_target_sparsity,
                        roofline)
from benchmarks.common import CACHE_DIR  # noqa: E402

BENCHES = [
    ("Table1/LongBench-E", bench_longbench_proxy),
    ("Table2/RULER", bench_ruler_proxy),
    ("Fig1a/sparsity-collapse", bench_sparsity_sweep),
    ("Fig1b+3b/head-vs-layer-decode", bench_head_vs_layer),
    ("Fig3a/prefill-speedup", bench_prefill_speedup),
    ("Fig5/target-sparsity", bench_target_sparsity),
    ("Fig6/continued-training", bench_continued_training),
    ("Fig7/data-balance", bench_data_balance),
    ("Fig9/router-overhead", bench_router_overhead),
    ("Serving/decode-speedup", bench_decode_speedup),
    ("Serving/continuous-batching", bench_continuous_batching),
    ("Serving/prefix-cache", bench_prefix_cache),
    ("Serving/degraded-mode", bench_degraded_mode),
    ("Roofline", roofline),
]


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    out_lines = ["name,us_per_call,derived"]
    print("name,us_per_call,derived")
    for label, mod in BENCHES:
        if only and only not in label:
            continue
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # noqa: BLE001
            print(f"{label}/ERROR,0.00,{type(e).__name__}: {e}")
            raise
        for r in rows:
            print(r.csv(), flush=True)
            out_lines.append(r.csv())
        print(f"# {label} done in {time.time() - t0:.1f}s", flush=True)
    # every BENCH_*.json a bench writes already lands under CACHE_DIR
    # (an absolute artifacts/bench/ path); the summary CSV goes to the
    # same place so CI uploads the directory as one artifact
    os.makedirs(CACHE_DIR, exist_ok=True)
    with open(os.path.join(CACHE_DIR, "results.csv"), "w") as f:
        f.write("\n".join(out_lines) + "\n")


if __name__ == "__main__":
    main()
