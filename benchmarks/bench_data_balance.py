"""Paper Fig. 7 / App. E.1: balanced vs skewed training mixtures —
skew homogenizes the router (per-task sparsity trajectories fail to
diverge)."""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import Row, live_msr, trained_model
from repro.data import mixture_iterator
from repro.train import RouterTrainer


def run() -> List[Row]:
    cfg, params0 = trained_model()
    rows: List[Row] = []
    mixtures = {
        "balanced": {"markov": 0.5, "needle": 0.5},
        "skewed-holistic": {"markov": 0.95, "needle": 0.05},
    }
    for name, weights in mixtures.items():
        rt = RouterTrainer(cfg, total_steps=150)
        state = rt.init(params0)
        it = mixture_iterator(cfg.vocab_size, 16, 96, seed=2,
                              weights=weights)
        state, _ = rt.run(state, it, 150, log_every=10 ** 9,
                          log_fn=lambda *_: None)
        params = rt.params(state)
        msr_r = live_msr(cfg, params, "needle")
        msr_h = live_msr(cfg, params, "markov")
        div = abs(msr_h - msr_r)
        rows.append(Row(f"data_balance/{name}", 0.0,
                        f"msr_retrieval={msr_r:.2f} "
                        f"msr_holistic={msr_h:.2f} divergence={div:.2f}"))
    return rows
