"""Shared benchmark context: a tiny flux model trained on the synthetic
mixture (cached across benches), timing helpers, CSV rows.

Measurement-boundary convention: jax dispatch is asynchronous, so any
wall-clock interval that brackets device work MUST end on an explicit
synchronization or the tail of the device time leaks into whatever is
timed next (async-dispatch bias).  ``time_call`` blocks on its own
output; phase-structured loops (e.g. "drain the scheduler, then stop
the clock") call ``device_sync`` at each boundary instead.  Every timer
in benchmarks/ follows this convention — new benches should too.

All BENCH_*.json / CSV outputs land under ``CACHE_DIR``
(artifacts/bench/ at the repo root, an absolute path so it does not
depend on the cwd); CI uploads that directory as one artifact.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.data import SyntheticTasks, mixture_iterator
from repro.models import model as MD
from repro.serve.telemetry import quantile, summarize  # noqa: F401
from repro.train import PretrainTrainer, RouterTrainer, checkpoint

CACHE_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                         "bench")
SEQ = 96


def device_sync(*trees) -> None:
    """Barrier at a measurement boundary: block until every array in
    ``trees`` (or, with no arguments, all live device arrays) has
    materialized, so the interval being closed actually contains its
    device work.  Host-side no-op when nothing is pending."""
    if trees:
        jax.block_until_ready(trees)
        return
    arrs = list(jax.live_arrays())
    if arrs:
        jax.block_until_ready(arrs)


def pct(xs: Iterable[float], q: float) -> float:
    """q-th percentile (0..100), NaN-filtered — the one percentile
    helper the benches share (serve.telemetry.quantile, the same
    estimator the metrics registry's digests use)."""
    return quantile(xs, q)


def latency_summary(xs: Iterable[float],
                    qs: Sequence[float] = (50, 95, 99)) -> Dict[str, float]:
    """{"p50": …, "p95": …, "p99": …} digest of a latency sample."""
    return summarize(xs, qs)


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.2f},{self.derived}"


def bench_cfg():
    base = smoke_variant(get_config("phi3-mini-3.8b"))
    return base.replace(
        num_layers=4,  # a little depth so layer routing has room
        vocab_size=64,
        flux=base.flux.replace(sink=4, local=16, pool_size=8))


def mixed_pattern(cfg):
    """Alternating fa/sa override over routed layers — pins one
    realistic mixed cache geometry so serving benches measure the
    scheduling/admission transformation, not router noise."""
    flip, out = True, []
    for k in cfg.layer_kinds:
        out.append(("fa" if flip else "sa") if k == "attn" else None)
        flip = not flip if k == "attn" else flip
    return tuple(out)


_CTX = {}


def trained_model(pre_steps: int = 450, router_steps: int = 120):
    """Pretrained backbone + trained router (cached on disk)."""
    if "model" in _CTX:
        return _CTX["model"]
    cfg = bench_cfg()
    os.makedirs(CACHE_DIR, exist_ok=True)
    ck = os.path.join(CACHE_DIR, "bench_model.msgpack")
    params = MD.init_params(jax.random.key(0), cfg)
    if os.path.exists(ck):
        params = checkpoint.load(ck, params)
    else:
        it = mixture_iterator(cfg.vocab_size, 16, SEQ, seed=0,
                              weights={"markov": 0.5, "needle": 0.5})
        pt = PretrainTrainer(cfg, total_steps=pre_steps, lr=3e-3)
        st = pt.init(params)
        st, _ = pt.run(st, it, pre_steps, log_every=10 ** 9,
                       log_fn=lambda *_: None)
        rt = RouterTrainer(cfg, total_steps=router_steps)
        rstate = rt.init(st["params"])
        rstate, _ = rt.run(rstate, it, router_steps, log_every=10 ** 9,
                           log_fn=lambda *_: None)
        params = rt.params(rstate)
        checkpoint.save(ck, params)
    _CTX["model"] = (cfg, params)
    return _CTX["model"]


def time_call(fn: Callable, *args, warmup: int = 2, iters: int = 5,
              **kw) -> float:
    """Median wall-clock μs of fn(*args) (block_until_ready-aware)."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def eval_accuracy(cfg, params, task: str, *, pattern=None, n: int = 32,
                  seq: int = SEQ, routing_ctx: Optional[str] = None,
                  head_split_n: int = 0, needle_pos=None,
                  seed: int = 42) -> float:
    """Answer-token accuracy from prefill logits."""
    gen = SyntheticTasks(cfg.vocab_size, seed=0)
    rng = np.random.default_rng(seed)
    kw = {}
    if task == "needle" and needle_pos is not None:
        kw["needle_pos"] = needle_pos
    b = gen.batch(rng, task, n, seq, **kw)
    toks = jnp.asarray(b.tokens)
    if routing_ctx == "head_split":
        out = MD.prefill(params, cfg, toks, routing_ctx="head_split",
                         head_split_n=head_split_n, want_cache=False)
    elif pattern is not None:
        out = MD.prefill(params, cfg, toks, routing_ctx="fixed",
                         fixed_pattern=jnp.asarray(pattern),
                         want_cache=False)
    elif routing_ctx:
        out = MD.prefill(params, cfg, toks, routing_ctx=routing_ctx,
                         want_cache=False)
    else:
        out = MD.prefill(params, cfg, toks, want_cache=False)
    pred = np.asarray(jnp.argmax(out.logits, -1))
    return float((pred == b.labels[:, -1]).mean())


def live_msr(cfg, params, task: str, n: int = 16, seq: int = SEQ,
             seed: int = 7) -> float:
    """Ω_MSR realized by the live router on a task."""
    gen = SyntheticTasks(cfg.vocab_size, seed=0)
    b = gen.batch(np.random.default_rng(seed), task, n, seq)
    out = MD.prefill(params, cfg, jnp.asarray(b.tokens),
                     want_cache=False)
    if out.routing is None:
        return float("nan")
    return float(1.0 - np.asarray(out.routing).mean())
