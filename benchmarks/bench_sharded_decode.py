"""Tensor-parallel pooled decode vs single-device (DESIGN.md
§Distributed serving).

Drains the same mixed-length workload through two engines — one
constructed without a mesh and one on a (1, 2) debug mesh with
head-sharded pool caches and tensor-parallel weights — and reports
tok/s for both plus the ratio.  Token streams are asserted identical
(the mesh is a layout transformation, not an approximation).

Also emits the collective-traffic analytic the mesh layout is judged
by: the pooled decode scan is lowered with mesh-committed inputs and
its compiled HLO walked with ``hlo_costs.loop_aware_costs`` — the
per-step collective bytes must be activation-sized (O(H·D) combines,
row-parallel all-reduces), a small fraction of even ONE layer's KV
cache, never the O(S·D) cache gather a naive sequence-sharded layout
lowers to.

Writes ``BENCH_sharded.json`` (gated by check_regression.py against
the committed baseline).  Needs ≥ 2 devices: run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` on CPU.
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict, List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (CACHE_DIR, Row, bench_cfg, device_sync,
                               mixed_pattern, pct)
from repro.launch import hlo_costs as HL
from repro.launch.mesh import make_debug_mesh
from repro.models import model as MD
from repro.serve import Request, ServeEngine

LENS = tuple(range(24, 56, 4))  # 8 unique prompt lengths


def _requests(cfg, n: int, n_steps: int, seed: int = 0) -> List[Request]:
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab_size,
                                        size=LENS[i % len(LENS)]
                                        ).astype(np.int32),
                    n_steps=n_steps)
            for i in range(n)]


def _drain_run(eng: ServeEngine, reqs: List[Request], *, slots: int,
               chunk: int) -> Dict:
    """Submit everything up front and drain: both legs then execute the
    identical tick/batch sequence, so the ratio isolates the layout."""
    sched = eng.scheduler(slots_per_bucket=slots, chunk=chunk)
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    done = eng.drain()
    device_sync()
    busy = time.perf_counter() - t0
    tokens = sum(f.metrics.n_generated for f in done.values())
    return {"tokens": tokens, "busy_s": busy,
            "tokens_per_sec": tokens / busy,
            "ttft_p50_s": pct([f.metrics.ttft for f in done.values()], 50),
            "geometries": sched.n_geometries(),
            "decode_executables": eng.decode_cache_size(),
            "outputs": {rid: f.tokens for rid, f in done.items()}}


def _collective_analytic(cfg, params, mesh, *, slots: int,
                         n_steps: int, max_len: int) -> Dict:
    """Lower the pooled decode scan with mesh-committed inputs and
    count collective bytes in the compiled HLO (loop-aware: the scan
    body's collectives multiply by the trip count)."""
    from repro.serve.engine import kv_cache_stats
    from repro.serve.slots import SlotPool
    eng = ServeEngine(params, cfg, max_len=max_len, mesh=mesh)
    pattern = mixed_pattern(cfg)
    logits_like = jnp.zeros((1, cfg.vocab_size), jnp.float32)
    pool = SlotPool.create(cfg, pattern, slots, max_len, logits_like,
                           mesh=mesh)
    lowered = eng._decode_many.lower(
        params=eng.params, logits=pool.logits, caches=pool.caches,
        pos=pool.pos, rng=jax.random.key(0), n_steps=n_steps,
        greedy=True, enc_out=None, fa_heads=None, duo_layers=None,
        unroll=eng.decode_unroll)
    cost = HL.loop_aware_costs(lowered.compile().as_text())
    stats = kv_cache_stats(pool.caches)
    n_attn = sum(k == "attn" for k in cfg.layer_kinds)
    per_layer = stats.payload_bytes / max(n_attn, 1)
    per_step = cost.coll_bytes / n_steps
    return {
        "n_steps": n_steps,
        "collective_bytes_total": cost.coll_bytes,
        "collective_bytes_per_step": per_step,
        "collective_bytes_by_kind": dict(cost.coll_by_kind),
        "pool_payload_bytes": stats.payload_bytes,
        "per_layer_cache_bytes": per_layer,
        # THE scaling claim: per-step collectives vs one layer's cache
        "per_step_frac_of_layer_cache": per_step / max(per_layer, 1.0),
    }


def run(n_requests: int = 12, n_steps: int = 48, slots: int = 8,
        chunk: int = 8) -> List[Row]:
    if len(jax.devices()) < 2:
        raise SystemExit(
            f"bench_sharded_decode: needs >= 2 devices, have "
            f"{len(jax.devices())} — set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=8 "
            f"before launch")
    cfg = bench_cfg()
    params = MD.init_params(jax.random.key(0), cfg)
    pattern = mixed_pattern(cfg)
    mesh = make_debug_mesh(1, 2)
    max_len = max(LENS) + n_steps + 2
    reqs = lambda: _requests(cfg, n_requests, n_steps)  # noqa: E731

    # separate engine per measured drain (drain closes the scheduler);
    # warm each leg once so compile time stays out of the timings, then
    # keep the best of ``reps`` interleaved runs (min-time estimator
    # under shared-host drift — common.py convention)
    reps = 3
    legs = {"single": {}, "mesh": {"mesh": mesh}}
    best: Dict[str, Dict] = {k: None for k in legs}
    for label, kw in legs.items():
        _drain_run(ServeEngine(params, cfg, max_len=max_len,
                               routing_override=pattern, **kw),
                   reqs(), slots=slots, chunk=chunk)
    for _ in range(reps):
        for label, kw in legs.items():
            m = _drain_run(ServeEngine(params, cfg, max_len=max_len,
                                       routing_override=pattern, **kw),
                           reqs(), slots=slots, chunk=chunk)
            if (best[label] is None
                    or m["tokens_per_sec"] > best[label]["tokens_per_sec"]):
                best[label] = m
    single, mesh_leg = best["single"], best["mesh"]
    # the mesh is a layout, not an approximation: identical tokens
    parity = all(np.array_equal(single["outputs"][rid],
                                mesh_leg["outputs"][rid])
                 for rid in single["outputs"])
    for leg in (single, mesh_leg):
        del leg["outputs"]
    analytic = _collective_analytic(cfg, params, mesh, slots=slots,
                                    n_steps=chunk, max_len=max_len)
    results = {
        "n_requests": n_requests, "n_steps": n_steps,
        "prompt_lens": list(LENS), "slots_per_bucket": slots,
        "chunk": chunk, "mesh_shape": [1, 2],
        "n_devices": len(jax.devices()),
        "single": single, "mesh": mesh_leg,
        "mesh_vs_single_ratio": (mesh_leg["tokens_per_sec"]
                                 / single["tokens_per_sec"]),
        "token_parity": parity,
        "collective_analytic": analytic,
    }
    os.makedirs(CACHE_DIR, exist_ok=True)
    with open(os.path.join(CACHE_DIR, "BENCH_sharded.json"), "w") as f:
        json.dump({"timestamp": time.time(),
                   "device": jax.default_backend(),
                   "results": results}, f, indent=2)
    frac = analytic["per_step_frac_of_layer_cache"]
    return [
        Row("sharded-decode/single", single["busy_s"] * 1e6,
            f"tps={single['tokens_per_sec']:.0f};"
            f"execs={single['decode_executables']}"),
        Row("sharded-decode/mesh-1x2", mesh_leg["busy_s"] * 1e6,
            f"tps={mesh_leg['tokens_per_sec']:.0f};"
            f"ratio={results['mesh_vs_single_ratio']:.2f}x;"
            f"parity={'ok' if parity else 'MISMATCH'};"
            f"execs={mesh_leg['decode_executables']}"),
        Row("sharded-decode/collectives", 0.0,
            f"per_step={analytic['collective_bytes_per_step']:.0f}B;"
            f"layer_cache={analytic['per_layer_cache_bytes']:.0f}B;"
            f"frac={frac:.3f}"),
    ]


def main() -> None:
    smoke = "--smoke" in sys.argv
    rows = (run(n_requests=6, n_steps=8, slots=4, chunk=4)
            if smoke else run())
    for r in rows:
        print(r.csv())
    data = json.load(open(os.path.join(CACHE_DIR, "BENCH_sharded.json")))
    res = data["results"]
    if not res["token_parity"]:
        print("# FAIL mesh tokens differ from single-device tokens")
        raise SystemExit(1)
    print("# ok mesh/single token parity")
    frac = res["collective_analytic"]["per_step_frac_of_layer_cache"]
    if frac >= 1.0:
        # a cache-sized collective per step means the layout regressed
        # to a gather — hard failure, not a perf warning
        print(f"# FAIL per-step collectives {frac:.2f}x one layer's "
              f"cache (must be activation-sized)")
        raise SystemExit(1)
    print(f"# ok per-step collectives = {frac:.3f}x one layer's cache")
    ratio = res["mesh_vs_single_ratio"]
    # CPU host-device meshes add real per-op overhead; the ratio is
    # advisory there (the gate tracks it via the committed baseline)
    print(f"# ok mesh 1x2 vs single throughput ratio {ratio:.2f}x"
          + (" (smoke shapes — advisory)" if smoke else ""))


if __name__ == "__main__":
    main()
