"""Chunked cache-resident prefill vs monolithic prefill→repack
(DESIGN.md §Prefill pipeline).

Two claims, measured on the same engine weights:

  wall-clock — admission latency (prefill + cache build) for a long
      prompt, monolithic (full-sequence prefill, host-planned repack)
      vs chunked (route on the first chunk, stream the rest directly
      into decode-geometry caches).  The chunked path should be no
      slower at 4k and strictly better as prompts grow: it never runs
      the second full pass over KV that repack is.
  peak SA-layer KV — the monolithic path materializes O(S) KV at every
      layer before repacking; the chunked path's live SA-layer state is
      the ring, whose size is independent of S.  BENCH_prefill.json
      records both so the perf trajectory can assert ring-boundedness.

Plus p50 TTFT under mixed prefill+decode continuous load: long prompts
admitted chunk-by-chunk (Sarathi-style mixed ticks) vs monolithic
admission that stalls the tick for a whole prefill.

Writes ``BENCH_prefill.json``; ``--smoke`` shrinks shapes for CI.
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict, List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (CACHE_DIR, Row, bench_cfg, device_sync,
                               mixed_pattern, pct)
from repro.models import model as MD
from repro.serve import ContinuousScheduler, Request, ServeEngine


def _sa_layer_bytes(caches, cfg, pattern) -> int:
    """KV bytes held for SA-routed layers in a decode-cache list."""
    total = 0
    for i, kind in enumerate(cfg.layer_kinds):
        if kind != "attn" or pattern[i] != "sa":
            continue
        for leaf in jax.tree.leaves(caches[i]):
            total += leaf.size * leaf.dtype.itemsize
    return total


def _monolithic_sa_bytes(pf_caches, cfg, pattern) -> int:
    """KV bytes the monolithic prefill materializes at SA layers."""
    P = MD.period_len(cfg)
    total = 0
    for i, kind in enumerate(cfg.layer_kinds):
        if kind != "attn" or pattern[i] != "sa":
            continue
        per, pos = divmod(i, P)
        c = jax.tree.map(lambda a: a[per], pf_caches[pos])
        for leaf in jax.tree.leaves(c):
            total += leaf.size * leaf.dtype.itemsize
    return total


def _time_once(fn) -> float:
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(jax.tree.leaves(out))
    return time.perf_counter() - t0


def bench_admission(cfg, params, seq_len: int, chunk: int,
                    reps: int = 3) -> Dict:
    pattern = mixed_pattern(cfg)
    max_len = seq_len + 64
    toks = jax.random.randint(jax.random.key(0), (1, seq_len), 0,
                              cfg.vocab_size)
    mono = ServeEngine(params, cfg, max_len=max_len, prefill_chunk=None,
                       routing_override=pattern)
    chnk = ServeEngine(params, cfg, max_len=max_len, prefill_chunk=chunk,
                       routing_override=pattern)
    # warm both (compile), then best-of-``reps`` with the two paths
    # interleaved — host CPU throughput drifts between runs, and
    # back-to-back blocks would time two different machines
    pf, _, caches_m, _ = mono.prefill_route_repack(toks)
    job = chnk.prefill_chunked(toks)
    t_mono = t_chnk = float("inf")
    for _ in range(reps):
        t_mono = min(t_mono, _time_once(
            lambda: mono.prefill_route_repack(toks)[2]))
        t_chnk = min(t_chnk, _time_once(
            lambda: chnk.prefill_chunked(toks).caches))
    sa_mono = _monolithic_sa_bytes(pf.caches, cfg, pattern)
    sa_chnk = _sa_layer_bytes(job.caches, cfg, pattern)
    return {
        "seq_len": seq_len, "chunk": chunk,
        "monolithic_s": t_mono, "chunked_s": t_chnk,
        "speedup": t_mono / t_chnk if t_chnk else float("nan"),
        "sa_peak_kv_bytes_monolithic": sa_mono,
        "sa_peak_kv_bytes_chunked": sa_chnk,
        "n_chunks": job.n_chunks,
    }


def bench_ttft(cfg, params, long_len: int, chunk: int,
               n_requests: int = 8) -> Dict:
    """p50 TTFT under mixed prefill+decode continuous load.

    Short prompts arrive *while* long prompts are being admitted: the
    monolithic scheduler's tick blocks on each full-prompt prefill, so
    a short arrival queues behind the whole long admission; the chunked
    scheduler streams at most ``prefill_chunks_per_tick`` chunks per
    tick, so short requests slip in between chunks and resident
    requests keep decoding.  TTFT is measured from each request's
    (staggered) arrival."""
    rng = np.random.default_rng(3)
    lens = [long_len if i % 2 == 0 else 16 + 4 * i
            for i in range(n_requests)]
    arrivals = np.cumsum(rng.exponential(0.25, size=n_requests))
    max_len = long_len + 64
    pattern = mixed_pattern(cfg)

    def drive(eng) -> Dict:
        sched = ContinuousScheduler(eng, slots_per_bucket=n_requests,
                                    chunk=4, prefill_chunks_per_tick=2)
        reqs = [Request(rid=i, tokens=rng.integers(
            0, cfg.vocab_size, size=lens[i]).astype(np.int32), n_steps=16)
            for i in range(n_requests)]
        pending = sorted(range(n_requests), key=lambda i: arrivals[i])
        done, tick_s = {}, []
        t0 = time.perf_counter()
        while len(done) < n_requests:
            now = time.perf_counter() - t0
            while pending and arrivals[pending[0]] <= now:
                sched.submit(reqs[pending.pop(0)])
            if sched.n_active() or sched.waiting:
                tt = time.perf_counter()
                for f in sched.tick():
                    done[f.rid] = f
                tick_s.append(time.perf_counter() - tt)
            elif pending:
                time.sleep(min(max(arrivals[pending[0]] - now, 0.0),
                               0.005))
        device_sync()  # measurement boundary (common.py docstring)
        ttft = sorted(f.metrics.ttft for f in done.values())
        return {
            "wall_s": time.perf_counter() - t0,
            "ttft_p50_s": pct(ttft, 50),
            "ttft_p95_s": pct(ttft, 95),
            # max tick duration = worst decode stall a resident request
            # sees while admissions happen (the mixed-tick claim)
            "max_tick_s": float(max(tick_s)),
            "p95_tick_s": pct(tick_s, 95),
            "prefill_chunk_ticks": sched.prefill_chunk_ticks,
        }

    out = {}
    for name, pc in (("monolithic", None), ("chunked", chunk)):
        eng = ServeEngine(params, cfg, max_len=max_len, prefill_chunk=pc,
                          routing_override=pattern)
        drive(eng)            # warm every executable on the real load
        out[name] = drive(eng)
    out["ttft_p50_ratio"] = (out["monolithic"]["ttft_p50_s"]
                             / max(out["chunked"]["ttft_p50_s"], 1e-9))
    # >1 means chunked admission bounds the worst decode stall tighter
    # than a monolithic full-prompt admission does
    out["decode_stall_ratio"] = (out["monolithic"]["max_tick_s"]
                                 / max(out["chunked"]["max_tick_s"], 1e-9))
    return out


def run(prompts=(4096, 16384), chunk: int = 512,
        ttft_long: int = 2048) -> List[Row]:
    cfg = bench_cfg()
    params = MD.init_params(jax.random.key(0), cfg)
    admission = [bench_admission(cfg, params, s, chunk) for s in prompts]
    ttft = bench_ttft(cfg, params, ttft_long, chunk)
    results = {"admission": admission, "ttft_mixed_load": ttft}
    os.makedirs(CACHE_DIR, exist_ok=True)
    with open(os.path.join(CACHE_DIR, "BENCH_prefill.json"), "w") as f:
        json.dump({"timestamp": time.time(),
                   "device": jax.default_backend(),
                   "results": results}, f, indent=2)
    rows = []
    for a in admission:
        rows.append(Row(
            f"prefill/chunked_vs_monolithic@{a['seq_len']}",
            a["chunked_s"] * 1e6,
            f"speedup={a['speedup']:.2f}x;"
            f"sa_kv={a['sa_peak_kv_bytes_chunked']};"
            f"sa_kv_mono={a['sa_peak_kv_bytes_monolithic']};"
            f"chunks={a['n_chunks']}"))
    rows.append(Row(
        "prefill/ttft_mixed_load", ttft["chunked"]["wall_s"] * 1e6,
        f"ttft_p50={ttft['chunked']['ttft_p50_s'] * 1e3:.0f}ms;"
        f"ttft_p50_mono={ttft['monolithic']['ttft_p50_s'] * 1e3:.0f}ms;"
        f"ratio={ttft['ttft_p50_ratio']:.2f}x;"
        f"stall={ttft['chunked']['max_tick_s'] * 1e3:.0f}ms;"
        f"stall_mono={ttft['monolithic']['max_tick_s'] * 1e3:.0f}ms;"
        f"stall_ratio={ttft['decode_stall_ratio']:.2f}x"))
    return rows


def main() -> None:
    smoke = "--smoke" in sys.argv
    rows = (run(prompts=(192, 384), chunk=32, ttft_long=96)
            if smoke else run())
    for r in rows:
        print(r.csv())
    data = json.load(open(os.path.join(CACHE_DIR, "BENCH_prefill.json")))
    ok = True
    for a in data["results"]["admission"]:
        # the structural claim is non-negotiable at any scale: SA-layer
        # live KV must not scale with the prompt
        if (a["sa_peak_kv_bytes_chunked"]
                >= a["sa_peak_kv_bytes_monolithic"]):
            print(f"# FAIL sa-layer peak KV not ring-bounded at "
                  f"{a['seq_len']}")
            ok = False
        if a["speedup"] < 1.0:
            print(f"# WARN chunked admission {a['speedup']:.2f}x at "
                  f"{a['seq_len']}"
                  + (" (smoke shapes — advisory)" if smoke else ""))
    if not ok:
        sys.exit(1)
    print("# ok chunked prefill: SA-layer peak KV ring-bounded")


if __name__ == "__main__":
    main()
