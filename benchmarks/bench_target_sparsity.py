"""Paper Fig. 5: effect of the retrieval-task budget t on the realized
Ω_MSR and accuracy (non-tight constraints ⇒ Ω need not equal t)."""
from __future__ import annotations

from typing import List

import jax
import numpy as np

from benchmarks.common import (Row, bench_cfg, eval_accuracy, live_msr,
                               trained_model)
from repro.data import mixture_iterator
from repro.models import model as MD
from repro.train import RouterTrainer

TARGETS = [0.25, 0.45, 0.65]


def run() -> List[Row]:
    cfg0, params0 = trained_model()  # reuse the pretrained backbone
    rows: List[Row] = []
    for t in TARGETS:
        cfg = cfg0.replace(flux=cfg0.flux.replace(target_retrieval=t))
        rt = RouterTrainer(cfg, total_steps=150)
        state = rt.init(params0)
        it = mixture_iterator(cfg.vocab_size, 16, 96, seed=1,
                              weights={"markov": 0.5, "needle": 0.5})
        state, hist = rt.run(state, it, 150, log_every=10 ** 9,
                             log_fn=lambda *_: None)
        params = rt.params(state)
        msr_r = live_msr(cfg, params, "needle")
        msr_h = live_msr(cfg, params, "markov")
        acc = eval_accuracy(cfg, params, "needle", routing_ctx="hard")
        rows.append(Row(
            f"target_sparsity/t={t}", 0.0,
            f"msr_retrieval={msr_r:.2f} msr_holistic={msr_h:.2f} "
            f"needle_acc={acc:.3f} "
            f"per_task_soft={hist[-1]['per_task_sparsity']}"))
    return rows
