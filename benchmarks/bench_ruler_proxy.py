"""Paper Table 2 (RULER proxy): needle retrieval vs context length —
length extrapolation under flux vs static sparsity."""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import Row, eval_accuracy, trained_model

LENGTHS = [96, 192, 384, 768]


def run() -> List[Row]:
    cfg, params = trained_model()
    rows: List[Row] = []
    for name, kw in {
        "FA": dict(routing_ctx="fa_only"),
        "flux": dict(routing_ctx="hard"),
        "all-SA": dict(pattern=np.zeros(cfg.num_layers, np.int64)),
    }.items():
        accs = [eval_accuracy(cfg, params, "needle", seq=s, **kw)
                for s in LENGTHS]
        derived = " ".join(f"L{s}={a:.3f}"
                           for s, a in zip(LENGTHS, accs))
        rows.append(Row(f"ruler_proxy/{name}", 0.0,
                        f"avg={np.mean(accs):.3f} {derived}"))
    return rows
