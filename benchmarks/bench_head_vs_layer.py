"""Paper Fig. 1(b) / Fig. 3(b): decode under layer-level vs head-level
sparsity.

Two views:
  * measured — CPU wall-clock of the jitted decode step (layer-level
    routing shrinks the cache the step actually reads);
  * derived  — v5e HBM-bytes-per-step roofline model: head-level
    sparsity still streams the FULL cache (ragged per-head histories
    are unrepresentable → no bandwidth saving), layer-level streams
    ring buffers for SA layers.  This is the paper's §2.3 argument made
    quantitative.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, time_call, trained_model
from repro.launch.mesh import HBM_BW
from repro.models import model as MD
from repro.serve import repack_caches
from repro.serve.engine import kv_cache_bytes

CTX = 4096  # simulated long-context length for the derived model


def _decode_bytes(cfg, pattern, ctx_len: int) -> float:
    """HBM bytes one decode step must stream (KV cache reads)."""
    flux = cfg.flux
    per_tok = 2 * cfg.num_kv_heads * cfg.head_dim * 2  # k+v bf16
    total = 0.0
    for i, kind in enumerate(cfg.layer_kinds):
        if kind != "attn":
            continue
        p = pattern[i]
        if p == "sa":
            total += per_tok * min(flux.sink + flux.local, ctx_len)
        elif isinstance(p, tuple) and p[0] == "duo":
            # head-level: full cache is still resident & streamed —
            # sparse heads' rows are *skipped compute*, not skipped DMA,
            # because the cache layout is (B, Hkv, S, D) contiguous in S.
            total += per_tok * ctx_len
        else:
            total += per_tok * ctx_len
    return total


def run() -> List[Row]:
    cfg, params = trained_model()
    S, B, N = 96, 2, 1
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (B, S + N)), jnp.int32)
    pf = MD.prefill(params, cfg, toks[:, :S], routing_ctx="fa_only")

    n_half = max(1, cfg.num_kv_heads // 2)
    patterns = {
        "dense-FA": tuple("fa" if k == "attn" else None
                          for k in cfg.layer_kinds),
        "layer-SA-0.5": tuple(
            ("sa" if (i % 2 == 0) else "fa") if k == "attn" else None
            for i, k in enumerate(cfg.layer_kinds)),
        "head-duo-0.5": tuple(
            ("duo", n_half) if k == "attn" else None
            for k in cfg.layer_kinds),
    }
    rows: List[Row] = []
    base_bytes = None
    for name, pattern in patterns.items():
        repack_pattern = tuple(
            "sa" if p == "sa" else ("fa" if p is not None else None)
            for p in pattern)
        caches = repack_caches(cfg, pf.caches, repack_pattern, S, S + N)
        dec = jax.jit(lambda c, t, p: MD.decode_step(
            params, cfg, t, c, pattern, p), static_argnums=())
        us = time_call(dec, caches, toks[:, S:S + 1], jnp.int32(S))
        hbm = _decode_bytes(cfg, pattern, CTX)
        if base_bytes is None:
            base_bytes = hbm
        v5e_us = hbm / HBM_BW * 1e6
        speedup = base_bytes / hbm
        rows.append(Row(
            f"head_vs_layer/{name}", us,
            f"kv_bytes={kv_cache_bytes(caches)} "
            f"v5e_step_us={v5e_us:.1f} derived_speedup={speedup:.2f}x"))
    return rows
