"""Pallas TPU flash-attention forward (causal / bidirectional).

Grid (BH, num_q_blocks, num_kv_blocks); the kv axis is the innermost,
sequentially-accumulated dimension (online softmax in VMEM scratch).
Block shapes default to 128 — the MXU-native tile (DESIGN.md §2).
Causal q-blocks skip kv blocks entirely above the diagonal via
``pl.when`` (FLOPs are truly skipped, unlike a masked dense rectangle).

GQA is handled without materializing repeated KV: the kv BlockSpec
index map folds the q-head → kv-head mapping
(kv_bh = b·Hkv + (h // group)).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names it TPUCompilerParams; jax >= 0.6 renamed it CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc, m_scr, l_scr, *, scale: float,
            block_q: int, block_k: int, causal: bool, seq_q: int,
            seq_k: int, q_offset: int):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    q_pos = q_offset + i * block_q + jax.lax.iota(jnp.int32, block_q)
    k_pos = j * block_k + jax.lax.iota(jnp.int32, block_k)
    # Block-level skip: any(k_pos <= max q_pos)?
    run = ((not causal)
           or (j * block_k <= q_offset + i * block_q + block_q - 1))

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        mask = (k_pos[None, :] < seq_k) & (q_pos[:, None] < q_offset + seq_q)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]          # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(-1, keepdims=True)
        acc[...] = acc[...] * alpha + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _fin():
        o_ref[0] = (acc[...] / jnp.maximum(l_scr[...], 1e-20)
                    ).astype(o_ref.dtype)


def flash_attention_bh(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       causal: bool = True, scale: Optional[float] = None,
                       block_q: int = 128, block_k: int = 128,
                       q_offset: int = 0,
                       interpret: bool = False) -> jax.Array:
    """q (BH, Sq, D); k/v (BHkv, Skv, D) with BH = BHkv·G.  Sq/Skv are
    padded to block multiples here; the mask keeps semantics exact."""
    BH, Sq, D = q.shape
    BHkv, Skv = k.shape[0], k.shape[1]
    G = BH // BHkv
    scale = D ** -0.5 if scale is None else scale
    Sq_p = -(-Sq // block_q) * block_q
    Skv_p = -(-Skv // block_k) * block_k
    q = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, Skv_p - Skv), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, Skv_p - Skv), (0, 0)))
    grid = (BH, Sq_p // block_q, Skv_p // block_k)

    kv_map = lambda b, i, j: (b // G, j, 0)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_q=block_q,
                          block_k=block_k, causal=causal, seq_q=Sq,
                          seq_k=Skv, q_offset=q_offset),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), kv_map),
            pl.BlockSpec((1, block_k, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq_p, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
