"""Pallas TPU streaming (sink + local) attention — the SSA prefill
kernel (paper Eq. 2 with the StreamingLLM geometry).

Per query block the grid's inner axis visits only
``n_sink_blocks + n_window_blocks`` kv blocks — O(S·(sink+local))
total, the paper's FLOP saving expressed structurally.  The kv
BlockSpec index map selects: sink blocks first, then the sliding
window around the query block (clamped at 0; overlap with the sink
region is masked out in the body, not double-counted).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names it TPUCompilerParams; jax >= 0.6 renamed it CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _win_start_block(i, *, block_q: int, block_k: int, local: int):
    """First kv block of q-block i's window (may dip into sink region)."""
    first_pos = i * block_q - (local - 1)
    return jnp.maximum(first_pos // block_k, 0)


def _kernel(q_ref, k_ref, v_ref, o_ref, acc, m_scr, l_scr, *, scale: float,
            block_q: int, block_k: int, sink: int, local: int, seq_q: int,
            seq_k: int, n_sink_blocks: int, q_offset: int, nkb: int):
    i = pl.program_id(1)
    j = pl.program_id(2)
    nsel = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    q_pos = q_offset + i * block_q + jax.lax.iota(jnp.int32, block_q)
    in_sink_part = j < n_sink_blocks
    wstart = _win_start_block(q_offset // block_q + i, block_q=block_q,
                              block_k=block_k, local=local)
    # must mirror the index map exactly (incl. the upper clamp)
    kv_block = jnp.where(in_sink_part, j,
                         jnp.minimum(wstart + (j - n_sink_blocks), nkb - 1))
    k_pos = kv_block * block_k + jax.lax.iota(jnp.int32, block_k)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    mask = (k_pos[None, :] <= q_pos[:, None]) & (k_pos[None, :] < seq_k)
    mask &= q_pos[:, None] < q_offset + seq_q
    # sink part: positions < sink only; window part: within `local` AND
    # >= sink (sink tokens are owned by the sink part — no double count).
    window_ok = ((q_pos[:, None] - k_pos[None, :]) < local) \
        & (k_pos[None, :] >= sink)
    sink_ok = k_pos[None, :] < sink
    mask &= jnp.where(in_sink_part, sink_ok, window_ok)
    # if the index-map clamped this window step onto an already-visited
    # block, drop the whole step (no double counting)
    unclamped = wstart + (j - n_sink_blocks)
    mask &= in_sink_part | (unclamped < nkb)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(-1, keepdims=True)
    acc[...] = acc[...] * alpha + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == nsel - 1)
    def _fin():
        o_ref[0] = (acc[...] / jnp.maximum(l_scr[...], 1e-20)
                    ).astype(o_ref.dtype)


def streaming_attention_bh(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           sink: int, local: int,
                           scale: Optional[float] = None,
                           block_q: int = 128, block_k: int = 128,
                           q_offset: int = 0,
                           interpret: bool = False) -> jax.Array:
    """q (BH,Sq,D), k/v (BHkv,Skv,D).  ``sink``/``local`` in tokens."""
    BH, Sq, D = q.shape
    BHkv, Skv = k.shape[0], k.shape[1]
    G = BH // BHkv
    scale = D ** -0.5 if scale is None else scale
    Sq_p = -(-Sq // block_q) * block_q
    Skv_p = -(-Skv // block_k) * block_k
    q = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, Skv_p - Skv), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, Skv_p - Skv), (0, 0)))
    nkb = Skv_p // block_k
    n_sink_blocks = min(-(-sink // block_k), nkb)
    # window span (local-1 back from block start .. block end)
    n_win_blocks = min((local - 1) // block_k + 1 + block_q // block_k, nkb)
    nsel = n_sink_blocks + n_win_blocks
    grid = (BH, Sq_p // block_q, nsel)

    def kv_map(b, i, j):
        wstart = _win_start_block(q_offset // block_q + i, block_q=block_q,
                                  block_k=block_k, local=local)
        blk = jnp.where(j < n_sink_blocks, j,
                        jnp.minimum(wstart + (j - n_sink_blocks), nkb - 1))
        return (b // G, blk, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_q=block_q,
                          block_k=block_k, sink=sink, local=local,
                          seq_q=Sq, seq_k=Skv, nkb=nkb,
                          n_sink_blocks=n_sink_blocks, q_offset=q_offset),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), kv_map),
            pl.BlockSpec((1, block_k, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq_p, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
