from repro.kernels import ops, ref  # noqa: F401
from repro.kernels.ops import (  # noqa: F401
    block_sparse_attention,
    decode_attention,
    flash_attention,
    streaming_attention,
)
