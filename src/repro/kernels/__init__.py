from repro.kernels import ops, ref  # noqa: F401
from repro.kernels.ops import (  # noqa: F401
    block_sparse_attention,
    decode_attention,
    decode_attention_pooled,
    flash_attention,
    streaming_attention,
)
