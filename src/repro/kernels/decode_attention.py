"""Pallas TPU flash-decode kernel: one query token vs a KV cache.

Split-KV with LSE accumulation: the grid's inner axis walks KV blocks;
VMEM scratch carries (acc, m, l).  Works for both the FullKV cache
(positions = arange, validity = pos ≤ cur) and the sink+local RingKV
cache (positions = ring slots' absolute positions, -1 = empty) — the
mask comes from a (L,) positions array, so one kernel serves every
decode mode of the paper's sparse-decode deployment (§3.3).

The decode phase is memory-bandwidth bound; the kernel's useful work
per HBM byte is fixed, so the paper's speedup comes from the *shape*
of the cache this kernel is pointed at (ring ≪ full), not from the
kernel itself — exactly the layer-level contiguity argument.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names it TPUCompilerParams; jax >= 0.6 renamed it CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, pos_ref, cur_ref, o_ref, acc, m_scr, l_scr,
            *, scale: float, block_k: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    q = q_ref[0].astype(jnp.float32)          # (1, D) — single token
    k = k_ref[0].astype(jnp.float32)          # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = pos_ref[...]                        # (1, bk) int32
    cur = cur_ref[0, 0]
    mask = (pos >= 0) & (pos <= cur)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(-1, keepdims=True)
    acc[...] = acc[...] * alpha + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _fin():
        o_ref[0] = (acc[...] / jnp.maximum(l_scr[...], 1e-20)
                    ).astype(o_ref.dtype)


def decode_attention_bh(q: jax.Array, k: jax.Array, v: jax.Array,
                        positions: jax.Array, cur_pos, *,
                        scale: Optional[float] = None, block_k: int = 128,
                        interpret: bool = False) -> jax.Array:
    """q (BH, 1, D); k/v (BHkv, L, D); positions (L,) int32 (-1 empty);
    cur_pos scalar int32.  Returns (BH, 1, D)."""
    BH, _, D = q.shape
    BHkv, L = k.shape[0], k.shape[1]
    G = BH // BHkv
    scale = D ** -0.5 if scale is None else scale
    L_p = -(-L // block_k) * block_k
    k = jnp.pad(k, ((0, 0), (0, L_p - L), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, L_p - L), (0, 0)))
    pos = jnp.pad(positions.astype(jnp.int32), (0, L_p - L),
                  constant_values=-1)[None, :]  # (1, L_p)
    cur = jnp.asarray(cur_pos, jnp.int32).reshape(1, 1)
    grid = (BH, L_p // block_k)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, j: (b // G, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, j: (b // G, j, 0)),
            pl.BlockSpec((1, block_k), lambda b, j: (0, j)),
            pl.BlockSpec((1, 1), lambda b, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, 1, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, D), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, pos, cur)
    return out


def make_kernel_decode_attn(*, block_k: int = 128,
                            min_len: int = 2 * 128,
                            interpret: Optional[bool] = None):
    """Adapter installing this kernel as the serving decode backend.

    Returns an fn matching ``repro.models.model.use_decode_attn``'s
    protocol: fn(q (B,Hq,1,D), k/v (B,Hkv,L,D), valid (L,) bool) →
    (B,Hq,1,D), or None to decline (per-KV-head masks from duo head
    splits, and rings shorter than ``min_len`` where the dense dot
    wins).  The (L,) validity mask is re-expressed in the kernel's
    positions/-1 vocabulary, so FullKV prefixes and RingKV occupancy
    masks both land on the same executable shape.
    """
    def fn(q: jax.Array, k: jax.Array, v: jax.Array,
           valid: jax.Array) -> Optional[jax.Array]:
        if valid.ndim != 1 or k.shape[2] < min_len:
            return None
        B, Hq, _, D = q.shape
        Hkv, L = k.shape[1], k.shape[2]
        positions = jnp.where(valid, jnp.arange(L, dtype=jnp.int32), -1)
        out = decode_attention_bh(
            q.reshape(B * Hq, 1, D), k.reshape(B * Hkv, L, D),
            v.reshape(B * Hkv, L, D), positions, jnp.int32(L),
            block_k=block_k,
            interpret=(jax.default_backend() != "tpu"
                       if interpret is None else interpret))
        return out.reshape(B, Hq, 1, D)
    return fn
