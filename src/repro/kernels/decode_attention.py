"""Pallas TPU flash-decode kernels: one query token vs a KV cache.

Split-KV with LSE accumulation: the grid's inner axis walks KV blocks;
VMEM scratch carries (acc, m, l).  Works for both the FullKV cache
(positions = arange, validity = pos ≤ cur) and the sink+local RingKV
cache (positions = ring slots' absolute positions, -1 = empty) — the
mask comes from a positions array, so one kernel serves every decode
mode of the paper's sparse-decode deployment (§3.3).

Two entry points:

* ``decode_attention_bh`` — single shared (L,) positions vector for the
  whole batch (the batch-synchronous ``generate`` path).
* ``decode_attention_pooled_bh`` — per-row (B,) live-prefix lengths and
  (B, L) positions for the continuous-batching slot pool, where every
  slot sits at a different decode depth.  The per-row length rides in
  as a scalar-prefetch operand so the KV BlockSpec index map clamps
  dead grid steps onto the last live block (the pipeline elides the
  repeat fetch → expressed HBM traffic scales with the live prefix) and
  ``pl.when`` short-circuits their compute — block *skipping*, not
  masking.

The decode phase is memory-bandwidth bound; the kernel's useful work
per HBM byte is fixed, so the paper's speedup comes from the *shape*
of the cache this kernel is pointed at (ring ≪ full, live ≪ capacity),
not from the kernel itself — exactly the layer-level contiguity
argument.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names it TPUCompilerParams; jax >= 0.6 renamed it CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30


@dataclass(frozen=True)
class PooledValid:
    """Per-slot decode validity, the pooled override vocabulary.

    ``mask`` is the dense-fallback boolean mask exactly as
    ``_dot_decode``'s einsum path expects it ((B, 1, L) for GQA
    caches, (B, S) for MLA absorbed decode); ``lengths`` is the (B,)
    int32 live-prefix count per slot (0 = dead/free slot); and
    ``positions`` is the optional (B, L) int32 absolute-position map
    with -1 marking empty ring entries — ``None`` means the trivial
    FullKV layout (slot i of the buffer holds position i) and lets the
    kernel synthesize arange rather than shipping it.
    """
    mask: jax.Array
    lengths: jax.Array
    positions: Optional[jax.Array] = None

    @property
    def ndim(self) -> int:
        # legacy adapters probe valid.ndim to decline non-1-D masks;
        # answering with the dense mask's rank keeps them declining
        # gracefully instead of crashing
        return self.mask.ndim


def _kernel(q_ref, k_ref, v_ref, pos_ref, cur_ref, o_ref, acc, m_scr, l_scr,
            *, scale: float, block_k: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    q = q_ref[0].astype(jnp.float32)          # (1, D) — single token
    k = k_ref[0].astype(jnp.float32)          # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = pos_ref[...]                        # (1, bk) int32
    cur = cur_ref[0, 0]
    mask = (pos >= 0) & (pos <= cur)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(-1, keepdims=True)
    acc[...] = acc[...] * alpha + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _fin():
        o_ref[0] = (acc[...] / jnp.maximum(l_scr[...], 1e-20)
                    ).astype(o_ref.dtype)


def decode_attention_bh(q: jax.Array, k: jax.Array, v: jax.Array,
                        positions: jax.Array, cur_pos, *,
                        scale: Optional[float] = None, block_k: int = 128,
                        interpret: bool = False) -> jax.Array:
    """q (BH, 1, D); k/v (BHkv, L, D); positions (L,) int32 (-1 empty);
    cur_pos scalar int32.  Returns (BH, 1, D)."""
    BH, _, D = q.shape
    BHkv, L = k.shape[0], k.shape[1]
    G = BH // BHkv
    scale = D ** -0.5 if scale is None else scale
    L_p = -(-L // block_k) * block_k
    k = jnp.pad(k, ((0, 0), (0, L_p - L), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, L_p - L), (0, 0)))
    pos = jnp.pad(positions.astype(jnp.int32), (0, L_p - L),
                  constant_values=-1)[None, :]  # (1, L_p)
    cur = jnp.asarray(cur_pos, jnp.int32).reshape(1, 1)
    grid = (BH, L_p // block_k)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, j: (b // G, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, j: (b // G, j, 0)),
            pl.BlockSpec((1, block_k), lambda b, j: (0, j)),
            pl.BlockSpec((1, 1), lambda b, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, 1, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, D), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, pos, cur)
    return out


def _pooled_kernel(len_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
                   acc, m_scr, l_scr, *, scale: float, block_k: int,
                   n_heads: int):
    """Grid (B·Hq, max-blocks); row b serves (batch b//Hq, head b%Hq).

    ``len_ref`` (scalar prefetch, (B,)) is the live-prefix length per
    slot; blocks past ``ceil(n / block_k)`` are short-circuited — their
    KV fetch was already clamped onto the last live block by the index
    map, so skipped steps cost neither HBM bytes nor FLOPs."""
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    n = len_ref[b // n_heads]
    nb = (n + block_k - 1) // block_k     # per-row traced trip count

    @pl.when(j < nb)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (1, Dk) — single token
        k = k_ref[0].astype(jnp.float32)  # (bk, Dk)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        col = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        # live prefix ∧ occupied ring entry (-1 = empty); FullKV rows
        # carry arange positions so only the prefix bound bites
        mask = (pos_ref[...] >= 0) & (col < n)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(-1, keepdims=True)
        acc[...] = acc[...] * alpha + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _fin():
        # n = 0 (free slot parked in the pool) finalizes acc=0 / l=0 →
        # zeros: finite garbage the scheduler never reads, matching the
        # dense path's convention that dead rows only need finiteness
        o_ref[0] = (acc[...] / jnp.maximum(l_scr[...], 1e-20)
                    ).astype(o_ref.dtype)


def decode_attention_pooled_bh(q: jax.Array, k: jax.Array, v: jax.Array,
                               positions: jax.Array, lengths: jax.Array,
                               *, n_heads: int,
                               scale: Optional[float] = None,
                               block_k: int = 128,
                               interpret: bool = False) -> jax.Array:
    """Batched pooled decode: q (B·Hq, 1, Dk); k (B·Hkv, L, Dk);
    v (B·Hkv, L, Dv); positions (B, L) int32 (-1 empty); lengths (B,)
    int32 live-prefix counts.  Dk may differ from Dv (MLA absorbed
    decode: Dk = R + rope, Dv = R).  Returns (B·Hq, 1, Dv)."""
    BH, _, Dk = q.shape
    BHkv, L = k.shape[0], k.shape[1]
    Dv = v.shape[2]
    G = BH // BHkv
    scale = Dk ** -0.5 if scale is None else scale
    L_p = -(-L // block_k) * block_k
    if L_p != L:
        k = jnp.pad(k, ((0, 0), (0, L_p - L), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, L_p - L), (0, 0)))
        positions = jnp.pad(positions.astype(jnp.int32),
                            ((0, 0), (0, L_p - L)), constant_values=-1)
    lengths = jnp.minimum(lengths.astype(jnp.int32), L)
    positions = positions.astype(jnp.int32)
    grid = (BH, L_p // block_k)

    def live_block(b, j, len_ref):
        # clamp dead steps onto the last live block: the pipeline sees
        # the same block index as the previous step and elides the
        # fetch, so HBM traffic tracks ceil(n / block_k), not L/block_k
        n = len_ref[b // n_heads]
        nb = jnp.maximum((n + block_k - 1) // block_k, 1)
        return jnp.minimum(j, nb - 1)

    def kv_map(b, j, len_ref):
        return (b // G, live_block(b, j, len_ref), 0)

    def pos_map(b, j, len_ref):
        return (b // n_heads, live_block(b, j, len_ref))

    out = pl.pallas_call(
        functools.partial(_pooled_kernel, scale=scale, block_k=block_k,
                          n_heads=n_heads),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, Dk), lambda b, j, lens: (b, 0, 0)),
                pl.BlockSpec((1, block_k, Dk), kv_map),
                pl.BlockSpec((1, block_k, Dv), kv_map),
                pl.BlockSpec((1, block_k), pos_map),
            ],
            out_specs=pl.BlockSpec((1, 1, Dv), lambda b, j, lens: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, Dv), jnp.float32),
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((BH, 1, Dv), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, q, k, v, positions)
    return out


def make_kernel_decode_attn(*, block_k: int = 128,
                            min_len: int = 2 * 128,
                            interpret: Optional[bool] = None):
    """Adapter installing these kernels as the serving decode backend.

    Returns an fn matching ``repro.models.model.use_decode_attn``'s
    protocol: fn(q (B,Hq,1,Dk), k/v (B,Hkv,L,D*), valid, scale=None) →
    (B,Hq,1,Dv), or None to decline.  ``valid`` is either the legacy
    (L,) shared mask (batch-synchronous ``generate``) — re-expressed in
    the kernel's positions/-1 vocabulary — or a :class:`PooledValid`
    carrying per-slot lengths/positions, which routes to the batched
    pooled kernel (FullKV, RingKV, and — via the q/k/v re-expression in
    ``attention.mla_absorbed_qkv`` — MLA absorbed decode).

    Declines (→ dense fallback) when the cache is shorter than
    ``min_len`` (the dense dot wins on tiny rings) or when the mask
    shape is one the kernels don't speak (per-KV-head duo masks).
    Every install/decline decision is appended to ``fn.trace_log`` as
    ``(event, reason)`` — the adapter is consulted at *trace* time
    (once per attention layer per executable), so the engine drains the
    log after each jit dispatch to drive its kernel-path counters.
    """
    trace_log: List[Tuple[str, str]] = []

    def _note(event: str, reason: str) -> None:
        trace_log.append((event, reason))

    def fn(q: jax.Array, k: jax.Array, v: jax.Array,
           valid, scale: Optional[float] = None) -> Optional[jax.Array]:
        interp = (jax.default_backend() != "tpu"
                  if interpret is None else interpret)
        if isinstance(valid, PooledValid):
            L = k.shape[2]
            if L < min_len:
                _note("decline", "min_len")
                return None
            B, Hq, _, Dk = q.shape
            Hkv, Dv = k.shape[1], v.shape[3]
            if valid.positions is None:
                positions = jnp.broadcast_to(
                    jnp.arange(L, dtype=jnp.int32)[None, :], (B, L))
            else:
                positions = valid.positions
            out = decode_attention_pooled_bh(
                q.reshape(B * Hq, 1, Dk), k.reshape(B * Hkv, L, Dk),
                v.reshape(B * Hkv, L, Dv), positions, valid.lengths,
                n_heads=Hq, scale=scale, block_k=block_k,
                interpret=interp)
            _note("hit", "pooled")
            return out.reshape(B, Hq, 1, Dv)
        if valid.ndim != 1 or k.shape[2] < min_len:
            _note("decline",
                  "mask_rank" if valid.ndim != 1 else "min_len")
            return None
        B, Hq, _, D = q.shape
        Hkv, L = k.shape[1], k.shape[2]
        positions = jnp.where(valid, jnp.arange(L, dtype=jnp.int32), -1)
        out = decode_attention_bh(
            q.reshape(B * Hq, 1, D), k.reshape(B * Hkv, L, D),
            v.reshape(B * Hkv, L, D), positions, jnp.int32(L),
            scale=scale, block_k=block_k, interpret=interp)
        _note("hit", "shared")
        return out.reshape(B, Hq, 1, D)

    def drain_log() -> List[Tuple[str, str]]:
        out = list(trace_log)
        trace_log.clear()
        return out

    fn.supports_pooled = True
    fn.supports_scale = True
    fn.trace_log = trace_log
    fn.drain_log = drain_log
    fn.block_k = block_k
    fn.min_len = min_len
    return fn
