"""Pure-jnp oracles for every kernel (independent of core.modes).

Each builds the full (Sq, Skv) mask and does a dense masked softmax —
O(S²) memory, test scale only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _masked_attention(q, k, v, mask, scale=None):
    """q (BH,Sq,D); k/v (BHkv,Skv,D); mask (Sq,Skv) or (BH,Sq,Skv)."""
    BH, Sq, D = q.shape
    BHkv = k.shape[0]
    G = BH // BHkv
    scale = D ** -0.5 if scale is None else scale
    q4 = q.reshape(BHkv, G, Sq, D)
    s = jnp.einsum("hgqd,hkd->hgqk", q4.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if mask.ndim == 3:
        mask = mask.reshape(BHkv, G, *mask.shape[1:])
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("hgqk,hkd->hgqd", p, v.astype(jnp.float32))
    return o.reshape(BH, Sq, D).astype(q.dtype)


def flash_attention_ref(q, k, v, *, causal=True, q_offset=0, scale=None):
    Sq, Skv = q.shape[1], k.shape[1]
    qp = q_offset + jnp.arange(Sq)
    kp = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask = kp[None, :] <= qp[:, None]
    return _masked_attention(q, k, v, mask, scale)


def streaming_attention_ref(q, k, v, *, sink, local, q_offset=0,
                            scale=None):
    Sq, Skv = q.shape[1], k.shape[1]
    qp = q_offset + jnp.arange(Sq)
    kp = jnp.arange(Skv)
    causal = kp[None, :] <= qp[:, None]
    window = (qp[:, None] - kp[None, :]) < local
    sink_m = kp[None, :] < sink
    return _masked_attention(q, k, v, causal & (window | sink_m), scale)


def decode_attention_ref(q, k, v, positions, cur_pos, scale=None):
    """q (BH,1,D); k/v (BHkv,L,D); positions (L,)."""
    valid = (positions >= 0) & (positions <= cur_pos)
    return _masked_attention(q, k, v, valid[None, :], scale)


def block_sparse_attention_ref(q, k, v, sel, *, block, scale=None):
    """sel (BH, nqb, K) — oracle expands selection to a dense mask."""
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    nqb = sel.shape[1]
    nkb = -(-Skv // block)
    # (BH, nqb, nkb) block visibility
    blk_mask = jnp.zeros((BH, nqb, nkb + 1), bool)
    sel_c = jnp.where(sel >= 0, sel, nkb)  # park invalid at the pad slot
    blk_mask = blk_mask.at[
        jnp.arange(BH)[:, None, None], jnp.arange(nqb)[None, :, None],
        sel_c].set(True)[:, :, :nkb]
    mask = jnp.repeat(jnp.repeat(blk_mask, block, 1), block, 2)
    mask = mask[:, :Sq, :Skv]
    qp, kp = jnp.arange(Sq), jnp.arange(Skv)
    mask &= (kp[None, :] <= qp[:, None])[None]
    return _masked_attention(q, k, v, mask, scale)
