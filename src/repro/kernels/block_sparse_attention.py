"""Pallas TPU block-sparse attention (XAttention execution kernel).

The jnp scorer (``repro.core.modes.antidiagonal_scores``) selects a
*static-K* set of kv blocks per query block; this kernel executes only
those blocks.  The selection indices arrive as a scalar-prefetch
operand (``PrefetchScalarGridSpec``) so the kv BlockSpec index map can
dereference them — the TPU analogue of the paper's block-sparse CUDA
kernel [13], with 128×128 MXU tiles instead of 64 (DESIGN.md §2).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names it TPUCompilerParams; jax >= 0.6 renamed it CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _kernel(sel_ref, off_ref, q_ref, k_ref, v_ref, o_ref, acc, m_scr,
            l_scr, *, scale: float, block: int, seq_q: int, seq_k: int):
    b = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    kv_block = sel_ref[b, i, j]

    # Duplicate selections must be resolved by the CALLER: this kernel
    # only *skips* entries ``dedupe_selection`` (or a causal truncator)
    # marked -1 — it has no cross-j view, so a repeated non-negative
    # index would be accumulated twice.  The skip is pl.when, not a
    # mask: a -1 step issues no MXU work (and its KV fetch collapses
    # onto a repeat of an already-resident block).
    @pl.when(kv_block >= 0)
    def _compute():
        row = i * block + jax.lax.iota(jnp.int32, block)
        q_pos = off_ref[0] + row            # absolute query positions
        k_pos = kv_block * block + jax.lax.iota(jnp.int32, block)
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = (k_pos[None, :] <= q_pos[:, None]) & (k_pos[None, :] < seq_k)
        mask &= row[:, None] < seq_q        # q padding rows
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(-1, keepdims=True)
        acc[...] = acc[...] * alpha + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _fin():
        o_ref[0] = (acc[...] / jnp.maximum(l_scr[...], 1e-20)
                    ).astype(o_ref.dtype)


def dedupe_selection(sel: jax.Array) -> jax.Array:
    """Mark repeated block indices (per row) as -1 (skipped by the
    kernel's mask).  sel (..., K) int32, assumed small K."""
    K = sel.shape[-1]
    eq = sel[..., :, None] == sel[..., None, :]
    first = jnp.tril(jnp.ones((K, K), bool), k=-1)
    dup = (eq & first).any(-1)
    return jnp.where(dup, -1, sel)


def block_sparse_attention_bh(q: jax.Array, k: jax.Array, v: jax.Array,
                              sel: jax.Array, *, q_offset=0,
                              scale: Optional[float] = None,
                              block: int = 128,
                              interpret: bool = False) -> jax.Array:
    """q (BH,Sq,D), k/v (BHkv,Skv,D), sel (BH, nqb, K) int32 kv-block
    indices per q block (use ``dedupe_selection`` first).

    ``q_offset`` (scalar int32, may be *traced*) offsets the causal
    comparison: query row r attends kv positions ≤ q_offset + r.  The
    chunked prefill passes its chunk ``start`` here, so every chunk of
    a bucket shares one executable — the offset rides in as a
    scalar-prefetch operand, not a static shape."""
    BH, Sq, D = q.shape
    BHkv, Skv = k.shape[0], k.shape[1]
    G = BH // BHkv
    scale = D ** -0.5 if scale is None else scale
    Sq_p = -(-Sq // block) * block
    Skv_p = -(-Skv // block) * block
    q = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, Skv_p - Skv), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, Skv_p - Skv), (0, 0)))
    nqb, K = sel.shape[1], sel.shape[2]
    assert nqb == Sq_p // block, (nqb, Sq_p, block)
    grid = (BH, nqb, K)
    off = jnp.asarray(q_offset, jnp.int32).reshape(1)

    def kv_map(b, i, j, sel_ref, off_ref):
        return (b // G, jnp.maximum(sel_ref[b, i, j], 0), 0)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block=block, seq_q=Sq,
                          seq_k=Skv),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block, D),
                             lambda b, i, j, s, o: (b, i, 0)),
                pl.BlockSpec((1, block, D), kv_map),
                pl.BlockSpec((1, block, D), kv_map),
            ],
            out_specs=pl.BlockSpec((1, block, D),
                                   lambda b, i, j, s, o: (b, i, 0)),
            scratch_shapes=[
                pltpu.VMEM((block, D), jnp.float32),
                pltpu.VMEM((block, 1), jnp.float32),
                pltpu.VMEM((block, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((BH, Sq_p, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(sel.astype(jnp.int32), off, q, k, v)
    return out[:, :Sq]
