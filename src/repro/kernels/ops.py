"""jit'd public wrappers around the Pallas kernels.

Inputs use the model layout (B, H, S, D); wrappers flatten to the
kernels' (B·H, S, D), choose interpret mode automatically (Python
interpretation on CPU, Mosaic on TPU), and jit with static geometry.

``use_pallas()`` is the global dispatch switch consulted by model code
(dry-run compiles the jnp path; TPU runtime flips to kernels).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.block_sparse_attention import (block_sparse_attention_bh,
                                                  dedupe_selection)
from repro.kernels.decode_attention import (decode_attention_bh,
                                            decode_attention_pooled_bh)
from repro.kernels.flash_attention import flash_attention_bh
from repro.kernels.streaming_attention import streaming_attention_bh


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _flatten(x):
    B, H, S, D = x.shape
    return x.reshape(B * H, S, D)


def _unflatten(x, B, H):
    BH, S, D = x.shape
    return x.reshape(B, H, S, D)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "q_offset", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, q_offset: int = 0,
                    interpret: Optional[bool] = None):
    """q (B,Hq,S,D); k/v (B,Hkv,S,D) → (B,Hq,S,D)."""
    interpret = default_interpret() if interpret is None else interpret
    B, H = q.shape[:2]
    out = flash_attention_bh(
        _flatten(q), _flatten(k), _flatten(v), causal=causal,
        block_q=block_q, block_k=block_k, q_offset=q_offset,
        interpret=interpret)
    return _unflatten(out, B, H)


@functools.partial(jax.jit, static_argnames=("sink", "local", "block_q",
                                             "block_k", "q_offset",
                                             "interpret"))
def streaming_attention(q, k, v, *, sink: int, local: int,
                        block_q: int = 128, block_k: int = 128,
                        q_offset: int = 0,
                        interpret: Optional[bool] = None):
    interpret = default_interpret() if interpret is None else interpret
    B, H = q.shape[:2]
    out = streaming_attention_bh(
        _flatten(q), _flatten(k), _flatten(v), sink=sink, local=local,
        block_q=block_q, block_k=block_k, q_offset=q_offset,
        interpret=interpret)
    return _unflatten(out, B, H)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k, v, positions, cur_pos, *, block_k: int = 128,
                     interpret: Optional[bool] = None):
    """q (B,Hq,1,D); k/v (B,Hkv,L,D); positions (L,); cur_pos scalar."""
    interpret = default_interpret() if interpret is None else interpret
    B, H = q.shape[:2]
    out = decode_attention_bh(
        _flatten(q), _flatten(k), _flatten(v), positions, cur_pos,
        block_k=block_k, interpret=interpret)
    return _unflatten(out, B, H)


@functools.partial(jax.jit, static_argnames=("block_k", "scale",
                                             "interpret"))
def decode_attention_pooled(q, k, v, positions, lengths, *,
                            block_k: int = 128,
                            scale: Optional[float] = None,
                            interpret: Optional[bool] = None):
    """Pooled decode: q (B,Hq,1,Dk); k/v (B,Hkv,L,D*); positions (B,L)
    int32 (-1 empty); lengths (B,) int32 live-prefix counts."""
    interpret = default_interpret() if interpret is None else interpret
    B, H = q.shape[:2]
    out = decode_attention_pooled_bh(
        _flatten(q), _flatten(k), _flatten(v), positions, lengths,
        n_heads=H, scale=scale, block_k=block_k, interpret=interpret)
    return _unflatten(out, B, H)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def block_sparse_attention(q, k, v, sel, *, q_offset=0, block: int = 128,
                           interpret: Optional[bool] = None):
    """sel (B,Hq,nqb,K) int32 kv-block indices (scorer output);
    ``q_offset`` (traced scalar ok) shifts the causal comparison for
    chunked callers."""
    interpret = default_interpret() if interpret is None else interpret
    B, H = q.shape[:2]
    sel = dedupe_selection(sel.reshape(B * H, *sel.shape[2:]))
    out = block_sparse_attention_bh(
        _flatten(q), _flatten(k), _flatten(v), sel, q_offset=q_offset,
        block=block, interpret=interpret)
    return _unflatten(out, B, H)
