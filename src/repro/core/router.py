"""The paper's Layer Router (§3.1).

Prefill-Suffix Pooling over the boundary ``pool_size`` tokens of the
layer's incoming query tensor → Context-Encoder MLP → Router-Head MLP →
2 routing logits (π_FA, π_SA).  Training uses Gumbel-Softmax soft
routing (Eq. 4); inference takes the argmax (hard routing, §3.3).

Router params are kept in float32: they are tiny (~2·d·hidden) and the
Gumbel relaxation is numerically touchy in bf16.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FluxConfig
from repro.models.layers import dense_init


def router_init(key, in_dim: int, flux: FluxConfig) -> Dict[str, jax.Array]:
    k1, k2, k3 = jax.random.split(key, 3)
    h = flux.router_hidden
    return {
        "enc_w": dense_init(k1, 2 * in_dim, h, jnp.float32),
        "enc_b": jnp.zeros((h,), jnp.float32),
        "head_w1": dense_init(k2, h, h, jnp.float32),
        "head_b1": jnp.zeros((h,), jnp.float32),
        "head_w2": dense_init(k3, h, 2, jnp.float32),
        "head_b2": jnp.zeros((2,), jnp.float32),
    }


def pool_prefix_suffix(x_q: jax.Array, pool_size: int) -> jax.Array:
    """(B, S, F) → (B, 2F): mean over the first / last ``pool_size`` tokens.

    Length-invariant by construction (paper Fig. 9): cost depends on
    ``pool_size``, not S.
    """
    p = min(pool_size, x_q.shape[1])
    prefix = jnp.mean(x_q[:, :p].astype(jnp.float32), axis=1)
    suffix = jnp.mean(x_q[:, -p:].astype(jnp.float32), axis=1)
    return jnp.concatenate([prefix, suffix], axis=-1)


def pool_prefix(x_q: jax.Array, pool_size: int) -> jax.Array:
    """Prefix-only pooling: the prefix mean fed to *both* encoder halves.

    The chunked serving prefill (DESIGN.md §Prefill pipeline) routes on
    the first chunk, before the suffix of the prompt exists.  Decisions
    must not depend on the chunking, so this variant pools only the
    first ``pool_size`` tokens — any chunk covering them yields the
    identical decision, and the monolithic path can reproduce it
    exactly (``routing_ctx="hard_prefix"``).  The router's 2F input
    layout is kept by duplicating the prefix mean into the suffix half.
    """
    p = min(pool_size, x_q.shape[1])
    prefix = jnp.mean(x_q[:, :p].astype(jnp.float32), axis=1)
    return jnp.concatenate([prefix, prefix], axis=-1)


def router_logits(params: Dict[str, jax.Array], x_q: jax.Array,
                  pool_size: int,
                  pooling: str = "prefix_suffix") -> jax.Array:
    """x_q (B, S, F) → logits (B, 2) = (π_FA, π_SA)."""
    pool = {"prefix_suffix": pool_prefix_suffix,
            "prefix": pool_prefix}[pooling]
    pooled = pool(x_q, pool_size)
    h = jax.nn.gelu(pooled @ params["enc_w"] + params["enc_b"])
    h = jax.nn.gelu(h @ params["head_w1"] + params["head_b1"])
    return h @ params["head_w2"] + params["head_b2"]


def soft_route(params: Dict[str, jax.Array], x_q: jax.Array,
               flux: FluxConfig, tau, rng) -> jax.Array:
    """Gumbel-Softmax relaxed routing weight r_soft ∈ (0,1) — the
    probability of selecting FA (paper Eq. 4).  Returns (B,)."""
    logits = router_logits(params, x_q, flux.pool_size)  # (B, 2)
    g = jax.random.gumbel(rng, logits.shape, jnp.float32)
    z = (logits + g) / jnp.maximum(tau, 1e-6)
    return jax.nn.softmax(z, axis=-1)[:, 0]


def hard_route(params: Dict[str, jax.Array], x_q: jax.Array,
               flux: FluxConfig, pooling: str = "prefix_suffix"
               ) -> Tuple[jax.Array, jax.Array]:
    """Deterministic inference routing (§3.3).

    Returns (r_hard (B,) ∈ {0,1} with 1 = FA, p_fa (B,) the underlying
    probability, useful for logging/consensus)."""
    logits = router_logits(params, x_q, flux.pool_size, pooling)
    p_fa = jax.nn.softmax(logits, axis=-1)[:, 0]
    return (logits[:, 0] > logits[:, 1]).astype(jnp.int32), p_fa


def sa_biased_threshold(level: int, *, step: float = 0.15,
                        max_level: int = 3) -> float:
    """FA-decision threshold for one rung of the load-adaptive sparsity
    ladder (serve/slo.py; ROADMAP "load-adaptive elastic sparsity").

    Hard routing picks FA when the pooled p_fa exceeds the threshold;
    the neutral rung (level 0) is the paper's argmax at 0.5, and each
    rung raises the bar by ``step`` so a pressured scheduler converts
    borderline-FA layers to SA.  Levels are **quantized and clamped**:
    the dial can only select thresholds on this ladder, so the set of
    reachable routing patterns — and therefore cache geometries — stays
    the same finite set the executable guard already counts, and the
    threshold never reaches 1.0 (which would force SA even at
    p_fa == 1 and make FA unreachable rather than merely disfavored).

    Monotone by construction: raising the level can only move layers
    FA → SA for a fixed prompt, never the reverse — the degradation
    ladder degrades, it does not oscillate quality.
    """
    lv = max(0, min(int(level), int(max_level)))
    return min(0.5 + lv * float(step), 0.999)


def decision_margin(p_fa: float, level: int, *, step: float = 0.15,
                    max_level: int = 3) -> float:
    """Signed score-vs-threshold margin of one hard routing decision:
    ``p_fa`` minus the rung's ``sa_biased_threshold`` (positive = the
    FA side of the cut, level 0 = the paper's 0.5 argmax).

    The serving telemetry observes this per routed layer at admission
    time (``flux_router_margin`` in DESIGN.md §Observability): a margin
    distribution hugging zero means the router is deciding on a knife
    edge — exactly the layers a sparsity-rung change will flip.
    """
    return float(p_fa) - sa_biased_threshold(level, step=step,
                                             max_level=max_level)


def prefix_routing_reusable(flux: FluxConfig, prefix_len: int,
                            seq_len: int, *, pooling: str = "prefix",
                            routable: bool = True) -> bool:
    """Can a routing decision taken on one prompt transfer *exactly* to
    another prompt sharing its first ``prefix_len`` tokens?

    This is the routing-compatibility check behind shared-prefix
    snapshot reuse (serve/prefix_cache.py).  Hard routing with
    prefix-only pooling depends on the first ``pool_size`` tokens of
    each layer's query tensor and nothing else, so two prompts agree
    iff both pool windows lie inside the shared prefix:

      * ``prefix_len >= pool_size`` — the publisher's decision was
        computed entirely from tokens the matcher also has;
      * ``seq_len >= pool_size`` — the matcher's own (hypothetical)
        pool window is the same ``pool_size`` tokens; a shorter prompt
        pools ``min(pool_size, S)`` tokens and may decide differently.

    Prefix+suffix pooling (the paper's default) reads the prompt tail,
    so its decisions are never prefix-transferable.  When the model has
    no routed layers (``routable=False``) there is no decision to
    disagree on and reuse is always exact.
    """
    if not routable:
        return True
    if pooling != "prefix":
        return False
    return prefix_len >= flux.pool_size and seq_len >= flux.pool_size


def anneal_tau(flux: FluxConfig, step, total_steps: int) -> jax.Array:
    """Linear temperature decay (paper §3.1)."""
    frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
    return flux.tau_start + (flux.tau_end - flux.tau_start) * frac


class MarginDriftTracker:
    """Decision-margin drift over the request stream, keyed by
    (layer, sa_level) rung.

    Pure-host bookkeeping (no jax): the serving engine feeds it the
    same per-layer ``decision_margin`` floats it already observes into
    the margin histograms.  Per key it keeps a Welford lifetime mean
    and a bounded window of recent margins; **drift** is
    ``recent_mean − lifetime_mean`` — positive drift at a rung means
    the router has been deciding more FA-ward than it historically did
    there, i.e. the traffic mix shifted under a fixed dial setting.
    That is the early-warning signal the load-adaptive sparsity dial
    needs before a rung change starts flipping layers (DESIGN.md
    §Observability)."""

    __slots__ = ("window", "_stats")

    def __init__(self, window: int = 256):
        if window < 1:
            raise ValueError(
                f"MarginDriftTracker: window={window} must be >= 1")
        self.window = int(window)
        # (layer, sa_level) -> [count, lifetime_mean, recent deque]
        self._stats: Dict[Tuple[int, int], list] = {}

    def observe(self, layer: int, sa_level: int, margin: float) -> None:
        key = (int(layer), int(sa_level))
        st = self._stats.get(key)
        if st is None:
            st = self._stats[key] = [0, 0.0, deque(maxlen=self.window)]
        st[0] += 1
        st[1] += (float(margin) - st[1]) / st[0]  # Welford mean
        st[2].append(float(margin))

    def drift(self, layer: int, sa_level: int) -> float:
        st = self._stats.get((int(layer), int(sa_level)))
        if st is None or not st[2]:
            return 0.0
        return sum(st[2]) / len(st[2]) - st[1]

    def keys(self) -> Tuple[Tuple[int, int], ...]:
        return tuple(sorted(self._stats))

    def report(self) -> Dict[str, Dict[str, float]]:
        """{"layer:level": {count, lifetime_mean, recent_mean, drift}}
        — JSON-ready for the drain summary / ledger report."""
        out: Dict[str, Dict[str, float]] = {}
        for (layer, level), st in sorted(self._stats.items()):
            recent = (sum(st[2]) / len(st[2])) if st[2] else 0.0
            out[f"{layer}:{level}"] = {
                "count": float(st[0]),
                "lifetime_mean": st[1],
                "recent_mean": recent,
                "drift": recent - st[1],
            }
        return out
