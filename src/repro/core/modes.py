"""Attention mode zoo: FA, sliding-window, SSA, Triangle, XAttention.

All modes share one blocked execution engine: a ``lax.map`` (scan) over
query blocks, so that (a) no full S×S score tensor is ever materialized
and (b) sparse modes only *express* the FLOPs they need — streaming
attention really does cost O(S·(sink+local)), visible in
``cost_analysis()`` of the lowered computation.  This is the pure-JAX
reference path; ``repro.kernels`` holds the Pallas TPU kernels that
mirror these semantics (validated against them in tests).

Layout convention: q is (B, Hq, Sq, D); k/v are (B, Hkv, Skv, D) with
Hq = G·Hkv (GQA).  Internally q is viewed as (B, Hkv, G, Sq, D).

TPU adaptation notes (DESIGN.md §2):
  * block sizes default to 128/512 multiples (MXU/VMEM alignment);
  * XAttention's dynamic threshold is realized as a *static* top-K block
    budget per query block (K = ceil((1-threshold)·num_kv_blocks)), since
    ragged per-row block counts are unrepresentable in static-shape XLA —
    the antidiagonal scoring estimator is kept.
"""
from __future__ import annotations

import contextlib
import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


@dataclass(frozen=True)
class AttnMode:
    kind: str  # full | window | streaming | triangle | block_topk
    causal: bool = True
    sink: int = 0
    local: int = 0
    chunk: int = 0
    block: int = 128
    stride: int = 16
    threshold: float = 0.9

    def replace(self, **kw) -> "AttnMode":
        return dataclasses.replace(self, **kw)


FULL = AttnMode("full")
BIDIRECTIONAL = AttnMode("full", causal=False)


def window_mode(window: int) -> AttnMode:
    return AttnMode("window", local=window)


def ssa_mode(flux) -> AttnMode:
    return AttnMode("streaming", sink=flux.sink, local=flux.local)


def xa_mode(flux) -> AttnMode:
    return AttnMode("block_topk", sink=flux.sink, local=flux.local,
                    block=flux.block, stride=flux.stride,
                    threshold=flux.threshold)


def ta_mode(flux) -> AttnMode:
    return AttnMode("triangle", sink=flux.sink, local=flux.local,
                    chunk=flux.chunk)


def sa_mode_for(flux) -> AttnMode:
    return {"ssa": ssa_mode, "xa": xa_mode, "ta": ta_mode}[flux.sa_mode](flux)


# ---------------------------------------------------------------------------
# Small helpers
# ---------------------------------------------------------------------------

def _pad_axis(x: jax.Array, axis: int, target: int) -> jax.Array:
    pad = target - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _softmax_attend(scores: jax.Array, v: jax.Array) -> jax.Array:
    """scores (..., Sq, Skv) f32 (already masked), v (..., Skv, D).

    v's batch rank is explicitly aligned to scores' (a GQA group axis may
    be missing from v); ellipsis broadcasting alone would right-align the
    wrong dims.
    """
    while v.ndim < scores.ndim:
        v = jnp.expand_dims(v, -3)
    m = jnp.max(scores, axis=-1, keepdims=True)
    # Guard fully-masked rows (can happen for padded queries).
    m = jnp.maximum(m, NEG_INF / 2)
    p = jnp.exp(scores - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-20)
    return jnp.einsum("...qk,...kd->...qd", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32)


def _gqa_view(q: jax.Array, num_kv_heads: int) -> jax.Array:
    B, Hq, Sq, D = q.shape
    G = Hq // num_kv_heads
    return q.reshape(B, num_kv_heads, G, Sq, D)


# ---------------------------------------------------------------------------
# Blocked engine
# ---------------------------------------------------------------------------

def attention(q: jax.Array, k: jax.Array, v: jax.Array, mode: AttnMode,
              *, q_offset=0, block_q: int = 512,
              scale: Optional[float] = None,
              split_depth: int = 0) -> jax.Array:
    """Blocked attention under ``mode``.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D).  ``q_offset`` shifts query
    positions (chunked prefill).  Returns (B, Hq, Sq, D) in q.dtype.

    ``split_depth`` (causal full attention only): recursively split the
    sequence in half — the lower half attends only to its own prefix.
    Dense-XLA causal attention otherwise expresses the full S×S
    rectangle (masked); depth d cuts the expressed FLOPs toward the
    2/3·S² limit (d=1 → 0.75, d=2 → 0.69, d=3 → 0.67).  A §Perf
    compute-term optimization; exactness is unaffected.
    """
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    scale = scale if scale is not None else D ** -0.5

    if (split_depth > 0 and mode.kind == "full" and mode.causal
            and q_offset == 0 and Sq == Skv and Sq >= 4 * block_q
            and Sq % 2 == 0):
        half = Sq // 2
        lower = attention(q[:, :, :half], k[:, :, :half], v[:, :, :half],
                          mode, block_q=block_q, scale=scale,
                          split_depth=split_depth - 1)
        upper = attention(q[:, :, half:], k, v, mode, q_offset=half,
                          block_q=block_q, scale=scale)
        return jnp.concatenate([lower, upper], axis=2)

    if mode.kind == "triangle":
        return _triangle(q, k, v, mode, q_offset=q_offset, block_q=block_q,
                         scale=scale)
    if mode.kind == "block_topk":
        return _block_topk(q, k, v, mode, q_offset=q_offset, scale=scale)

    q5 = _gqa_view(q, Hkv)  # (B, Hkv, G, Sq, D)
    bq = min(block_q, max(Sq, 1))
    Sq_pad = -(-Sq // bq) * bq
    q5 = _pad_axis(q5, 3, Sq_pad)
    nqb = Sq_pad // bq
    q_blocks = jnp.moveaxis(
        q5.reshape(B, Hkv, q5.shape[2], nqb, bq, D), 3, 0)

    kv_pos = jnp.arange(Skv)

    if mode.kind == "full":
        def body(args):
            i, qb = args
            q_pos = q_offset + i * bq + jnp.arange(bq)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, k,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((bq, Skv), bool)
            if mode.causal:
                mask &= kv_pos[None, :] <= q_pos[:, None]
            s = jnp.where(mask, s, NEG_INF)
            return _softmax_attend(s, v)

    elif mode.kind in ("window", "streaming"):
        # Local slice of length L (static); optional sink prefix.
        local = max(mode.local, 1)
        L = min(local + bq, Skv) if Skv >= local + bq else local + bq
        k_pad = _pad_axis(k, 2, max(Skv, L))
        v_pad = _pad_axis(v, 2, max(Skv, L))
        Skv_pad = k_pad.shape[2]
        sink_len = min(mode.sink, Skv) if mode.kind == "streaming" else 0

        def body(args):
            i, qb = args
            q_start = q_offset + i * bq
            q_pos = q_start + jnp.arange(bq)
            start = jnp.clip(q_start - local + 1, 0, Skv_pad - L)
            k_loc = lax.dynamic_slice_in_dim(k_pad, start, L, axis=2)
            v_loc = lax.dynamic_slice_in_dim(v_pad, start, L, axis=2)
            loc_pos = start + jnp.arange(L)
            s_loc = jnp.einsum("bhgqd,bhkd->bhgqk", qb, k_loc,
                               preferred_element_type=jnp.float32) * scale
            mask_loc = (loc_pos[None, :] <= q_pos[:, None])
            mask_loc &= (q_pos[:, None] - loc_pos[None, :]) < local
            mask_loc &= loc_pos[None, :] < Skv  # padding validity
            if sink_len > 0:
                # sink tokens are always visible; avoid double counting by
                # excluding them from the local part.
                mask_loc &= loc_pos[None, :] >= sink_len
                s_snk = jnp.einsum(
                    "bhgqd,bhkd->bhgqk", qb, k_pad[:, :, :sink_len],
                    preferred_element_type=jnp.float32) * scale
                mask_snk = kv_pos[None, :sink_len] <= q_pos[:, None]
                s = jnp.concatenate(
                    [jnp.where(mask_snk, s_snk, NEG_INF),
                     jnp.where(mask_loc, s_loc, NEG_INF)], axis=-1)
                vv = jnp.concatenate([v_pad[:, :, :sink_len], v_loc], axis=2)
                return _softmax_attend(s, vv)
            s_loc = jnp.where(mask_loc, s_loc, NEG_INF)
            return _softmax_attend(s_loc, v_loc)

    else:  # pragma: no cover
        raise ValueError(f"unknown mode kind {mode.kind!r}")

    out = lax.map(body, (jnp.arange(nqb), q_blocks))
    out = jnp.moveaxis(out, 0, 3)  # (B,Hkv,G,nqb,bq,Dv)
    out = out.reshape(B, Hkv, -1, Sq_pad, Dv)[:, :, :, :Sq]
    return out.reshape(B, Hq, Sq, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# Position-explicit masked attention (chunked cache-resident prefill)
# ---------------------------------------------------------------------------
#
# A ring decode cache stores keys out of positional order (slot index ≠
# absolute position), so the blocked engine above — which derives key
# positions from array offsets — cannot attend over it.  The chunked
# prefill instead carries explicit per-slot positions and masks against
# them; the kv extent (ring + chunk) is small, so a single dense masked
# softmax is the right shape on TPU.

def streaming_valid(q_positions: jax.Array, kv_positions: jax.Array,
                    sink: int, local: int) -> jax.Array:
    """Sink+local visibility by absolute position.

    q_positions (Sq,) or (B, Sq); kv_positions (B, L) with -1 = empty
    slot.  Returns (B, Sq, L) bool.  ``sink=0`` degenerates to a pure
    sliding window (the "local" layer kind).
    """
    q = (q_positions[None, :, None] if q_positions.ndim == 1
         else q_positions[:, :, None])
    kv = kv_positions[:, None, :]
    vis = (kv >= 0) & (kv <= q)
    return vis & ((kv < sink) | (q - kv < local))


# Execution backend for ``chunk_causal_attention``: "dense" is the
# fori_loop of masked einsums below; "pallas" routes to the
# block-sparse selected-block kernel (kernels/block_sparse_attention),
# which *skips* dead kv blocks instead of masking them; "auto" (the
# ambient default) picks pallas on TPU and dense elsewhere, so CPU
# tier-1 runs stay bitwise those of the reference path.  Like
# ``model.use_decode_attn`` this is trace-time ambient state, not part
# of any jit key — callers must install the same backend around every
# trace of a given executable (the serving engine never switches
# mid-lifetime).
_CHUNK_ATTN_BACKEND = []

CHUNK_ATTN_BACKENDS = ("auto", "dense", "pallas")


@contextlib.contextmanager
def chunk_attention_backend(backend: str, *, block: int = 128,
                            interpret: Optional[bool] = None):
    """Select the chunked-prefill attention engine (see above).
    ``block`` is the Pallas kernel's MXU tile; ``interpret`` forces
    interpret mode (None = interpret off-TPU, the testing convention)."""
    if backend not in CHUNK_ATTN_BACKENDS:
        raise ValueError(
            f"chunk_attention_backend: {backend!r} not in "
            f"{CHUNK_ATTN_BACKENDS}")
    _CHUNK_ATTN_BACKEND.append((backend, block, interpret))
    try:
        yield
    finally:
        _CHUNK_ATTN_BACKEND.pop()


def _chunk_backend() -> Tuple[str, int, Optional[bool]]:
    backend, block, interpret = (_CHUNK_ATTN_BACKEND[-1]
                                 if _CHUNK_ATTN_BACKEND
                                 else ("auto", 128, None))
    if backend == "auto":
        backend = ("pallas" if jax.default_backend() == "tpu"
                   else "dense")
    return backend, block, interpret


def _chunk_causal_block_sparse(q: jax.Array, k: jax.Array, v: jax.Array,
                               start: jax.Array, *, block: int,
                               scale: Optional[float],
                               interpret: Optional[bool]) -> jax.Array:
    """``chunk_causal_attention`` on the block-sparse Pallas kernel.

    The causal structure is expressed as a per-query-block *selection*:
    query block i (absolute rows [start+i·block, …)) selects kv blocks
    [0, last_vis(i)] and marks the rest -1, which the kernel skips via
    ``pl.when`` — dead blocks cost no MXU work.  ``start`` rides into
    the kernel as a traced scalar-prefetch operand (the causal offset),
    so every chunk of a bucket still shares one executable."""
    from repro.kernels.block_sparse_attention import \
        block_sparse_attention_bh
    B, Hq, C, D = q.shape
    Hkv, M = k.shape[1], k.shape[2]
    nqb = -(-C // block)
    K = -(-M // block)
    qb = jnp.arange(nqb)
    kb = jnp.arange(K)
    # last kv block any live row of query block i can see; rows past C
    # are padding (masked in-kernel), so bound by the last live row
    last_vis = (start + jnp.minimum((qb + 1) * block, C) - 1) // block
    sel = jnp.where(kb[None, :] <= last_vis[:, None], kb[None, :], -1)
    sel = jnp.broadcast_to(sel[None], (B * Hq, nqb, K)).astype(jnp.int32)
    out = block_sparse_attention_bh(
        q.reshape(B * Hq, C, D), k.reshape(B * Hkv, M, D),
        v.reshape(B * Hkv, M, D), sel, q_offset=start, scale=scale,
        block=block,
        interpret=(jax.default_backend() != "tpu"
                   if interpret is None else interpret))
    return out.reshape(B, Hq, C, out.shape[-1])


def chunk_causal_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                           start: jax.Array, *, kv_block: int = 512,
                           scale: Optional[float] = None) -> jax.Array:
    """Causal attention of a chunk of queries over a cache buffer.

    q (B,Hq,C,D) at absolute positions [start, start+C); k/v
    (B,Hkv,M,D) hold valid keys at positions [0, start+C) of an
    M-capacity buffer (everything beyond is zeros).  Flash-style
    online-softmax over kv blocks with a **traced trip count**
    ``ceil((start+C)/kv_block)`` — the expressed compute scales with
    the live prefix, not the buffer capacity, so early chunks of a
    chunked prefill don't pay for cache they haven't written yet
    (a dense masked call over M would: XLA cannot skip masked FLOPs).
    ``start`` stays traced, preserving one executable per chunk bucket.

    Under the "pallas" backend (``chunk_attention_backend``; the
    default "auto" resolves to it on TPU) the same contract executes on
    the block-sparse kernel via ``_chunk_causal_block_sparse``.
    """
    B, Hq, C, D = q.shape
    if v.shape[-1] == D:  # the kernel assumes Dk == Dv (GQA layers)
        backend, blk, interp = _chunk_backend()
        if backend == "pallas":
            return _chunk_causal_block_sparse(q, k, v, start, block=blk,
                                              scale=scale,
                                              interpret=interp)
    Hkv, M = k.shape[1], k.shape[2]
    G = Hq // Hkv
    Dv = v.shape[-1]
    scale = scale if scale is not None else D ** -0.5
    kb = min(kv_block, M)
    q5 = q.reshape(B, Hkv, G, C, D)
    q_pos = start + jnp.arange(C)
    nb = (start + C + kb - 1) // kb  # traced: only live blocks run
    neg = jnp.float32(NEG_INF)

    def body(j, carry):
        m, l, acc = carry
        # clamp so the final block stays in bounds; the >= j*kb mask
        # term drops any keys the clamp re-reads from the prior block
        s0 = jnp.minimum(j * kb, M - kb)
        ks = lax.dynamic_slice_in_dim(k, s0, kb, axis=2)
        vs = lax.dynamic_slice_in_dim(v, s0, kb, axis=2)
        kv_pos = s0 + jnp.arange(kb)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", q5, ks,
                       preferred_element_type=jnp.float32) * scale
        mask = ((kv_pos[None, :] <= q_pos[:, None])
                & (kv_pos[None, :] >= j * kb))
        s = jnp.where(mask[None, None, None], s, neg)
        m2 = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        m2 = jnp.maximum(m2, neg / 2)  # guard fully-masked rows
        p = jnp.exp(s - m2)
        corr = jnp.exp(m - m2)
        l = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p.astype(v.dtype), vs,
            preferred_element_type=jnp.float32)
        return m2, l, acc

    m0 = jnp.full((B, Hkv, G, C, 1), neg, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, C, 1), jnp.float32)
    acc0 = jnp.zeros((B, Hkv, G, C, Dv), jnp.float32)
    _, l, acc = lax.fori_loop(0, nb, body, (m0, l0, acc0))
    out = acc / jnp.maximum(l, 1e-20)
    return out.reshape(B, Hq, C, Dv).astype(q.dtype)


def masked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     valid: jax.Array,
                     scale: Optional[float] = None) -> jax.Array:
    """q (B,Hq,Sq,D), k/v (B,Hkv,L,D), valid (B, 1|Hkv, Sq, L) bool.

    Dense masked softmax attention with caller-supplied validity — no
    positional assumptions about the key layout.  Returns (B,Hq,Sq,Dv)
    in q.dtype."""
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    Dv = v.shape[-1]
    scale = scale if scale is not None else D ** -0.5
    q5 = _gqa_view(q, Hkv)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", q5, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid[:, :, None], s, NEG_INF)
    o = _softmax_attend(s, v)
    return o.reshape(B, Hq, Sq, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# Triangle (TriangleMix): streaming body + dense last chunk
# ---------------------------------------------------------------------------

def _triangle(q, k, v, mode: AttnMode, *, q_offset, block_q, scale):
    B, Hq, Sq, D = q.shape
    chunk = mode.chunk
    boundary = max(0, Sq - chunk)
    stream = mode.replace(kind="streaming")
    if boundary == 0:
        return attention(q, k, v, FULL, q_offset=q_offset, block_q=block_q,
                         scale=scale)
    out_pre = attention(q[:, :, :boundary], k, v, stream, q_offset=q_offset,
                        block_q=block_q, scale=scale)
    out_last = attention(q[:, :, boundary:], k, v, FULL,
                         q_offset=q_offset + boundary, block_q=block_q,
                         scale=scale)
    return jnp.concatenate([out_pre, out_last], axis=2)


# ---------------------------------------------------------------------------
# XAttention: antidiagonal block scoring + static top-K block selection
# ---------------------------------------------------------------------------

def xa_keep_blocks(num_kv_blocks: int, threshold: float) -> int:
    """Static per-q-block KV-block budget (TPU adaptation of the paper's
    cumulative-softmax-mass threshold; see module docstring)."""
    return max(2, min(num_kv_blocks,
                      int(-(-(1.0 - threshold) * num_kv_blocks // 1))))


def antidiagonal_scores(q: jax.Array, k: jax.Array, block: int,
                        stride: int, scale: float) -> jax.Array:
    """XAttention block importance estimate.

    q (B,K,G,Sq,D), k (B,K,Skv,D), both already padded to ``block``.
    Samples every ``stride``-th antidiagonal element of each (block×block)
    score tile: score(i,j) = logsumexp over sampled q_r·k_c with
    r+c ≡ 0 (mod stride) realized by pairing strided q rows with strided,
    reversed k rows.  Returns (B,K,G,nqb,nkb) f32.
    """
    B, K, G, Sq, D = q.shape
    Skv = k.shape[2]
    nqb, nkb = Sq // block, Skv // block
    m = block // stride
    # strided q rows: r = s·stride ; matching antidiagonal k col within the
    # tile: c = block-1-r  →  take k rows reversed then strided.
    qs = q.reshape(B, K, G, nqb, block, D)[:, :, :, :, ::stride]
    ks = k.reshape(B, K, nkb, block, D)[:, :, :, ::-1][:, :, :, ::stride]
    # sampled dot per (q block, k block): (m, m) grid of pairwise dots —
    # approximates m antidiagonals; reduce with logsumexp (softmax-mass
    # proxy per the paper's selection-by-mass rule).
    s = jnp.einsum("bkgqrd,bkncd->bkgqnrc", qs, ks,
                   preferred_element_type=jnp.float32) * scale
    return jax.nn.logsumexp(s, axis=(-2, -1))


def _block_topk(q, k, v, mode: AttnMode, *, q_offset, scale):
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    blk = mode.block
    Sq_pad = -(-Sq // blk) * blk
    Skv_pad = -(-Skv // blk) * blk
    q5 = _pad_axis(_gqa_view(q, Hkv), 3, Sq_pad)
    k_p = _pad_axis(k, 2, Skv_pad)
    v_p = _pad_axis(v, 2, Skv_pad)
    nqb, nkb = Sq_pad // blk, Skv_pad // blk
    keep = xa_keep_blocks(nkb, mode.threshold)

    scores = antidiagonal_scores(q5, k_p, blk, mode.stride, scale)
    # causal at block granularity + force sink block 0 and the diagonal.
    qb_idx = q_offset // blk + jnp.arange(nqb)
    kb_idx = jnp.arange(nkb)
    causal_blk = kb_idx[None, :] <= qb_idx[:, None]
    scores = jnp.where(causal_blk, scores, NEG_INF)
    forced = (kb_idx[None, :] == 0) | (kb_idx[None, :] == qb_idx[:, None])
    scores = jnp.where(forced, jnp.inf, scores)
    # static top-K kv blocks per q block
    _, sel = lax.top_k(scores, keep)  # (B,K,G,nqb,keep)

    G = q5.shape[2]
    k_blocks = k_p.reshape(B, Hkv, nkb, blk, D)
    v_blocks = v_p.reshape(B, Hkv, nkb, blk, Dv)
    kv_pos = jnp.arange(Skv_pad).reshape(nkb, blk)

    def body(args):
        i, qb, sel_i = args  # qb (B,K,G,blk,D); sel_i (B,K,G,keep)
        # gather selected kv blocks: (B,K,G,keep,blk,D)
        kg = jnp.take_along_axis(k_blocks[:, :, None],
                                 sel_i[..., None, None], axis=3)
        vg = jnp.take_along_axis(v_blocks[:, :, None],
                                 sel_i[..., None, None], axis=3)
        pos = kv_pos[sel_i]  # (B,K,G,keep,blk)
        q_pos = q_offset + i * blk + jnp.arange(blk)
        s = jnp.einsum("bkgqd,bkgnld->bkgqnl", qb, kg,
                       preferred_element_type=jnp.float32) * scale
        mask = pos[:, :, :, None] <= q_pos[None, None, None, :, None, None]
        mask &= (pos < Skv)[:, :, :, None]
        s = jnp.where(mask, s, NEG_INF)
        s = s.reshape(*s.shape[:4], keep * blk)
        vg = vg.reshape(B, Hkv, G, keep * blk, Dv)
        return _softmax_attend(s, vg)

    q_blocks = jnp.moveaxis(q5.reshape(B, Hkv, G, nqb, blk, D), 3, 0)
    sel_blocks = jnp.moveaxis(sel, 3, 0)
    out = lax.map(body, (jnp.arange(nqb), q_blocks, sel_blocks))
    out = jnp.moveaxis(out, 0, 3).reshape(B, Hkv, G, Sq_pad, Dv)[:, :, :, :Sq]
    return out.reshape(B, Hq, Sq, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# Head-level split (DuoAttention / PruLong baselines)
# ---------------------------------------------------------------------------

def head_split_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                         n_fa_kv: int, sa: AttnMode, *, q_offset=0,
                         block_q: int = 512) -> jax.Array:
    """Static head-level hybrid: the first ``n_fa_kv`` KV heads (and
    their GQA query groups) run full attention, the rest run ``sa``.

    This is the paper's *baseline* (DuoAttention/PruLong); splitting is
    at KV-head granularity, which is what those methods use on GQA
    models.  Note the decode-phase criticism (paper §2.3): the ragged
    per-head history cannot shrink the cache — see
    ``repro.models.model`` decode path.
    """
    Hkv = k.shape[1]
    G = q.shape[1] // Hkv
    n_fa_q = n_fa_kv * G
    o_fa = attention(q[:, :n_fa_q], k[:, :n_fa_kv], v[:, :n_fa_kv], FULL,
                     q_offset=q_offset, block_q=block_q)
    if n_fa_kv == Hkv:
        return o_fa
    o_sa = attention(q[:, n_fa_q:], k[:, n_fa_kv:], v[:, n_fa_kv:], sa,
                     q_offset=q_offset, block_q=block_q)
    return jnp.concatenate([o_fa, o_sa], axis=1)


# ---------------------------------------------------------------------------
# FLOP model (napkin math for roofline / benchmarks)
# ---------------------------------------------------------------------------

def mode_flops(mode: AttnMode, Sq: int, Skv: int, num_heads: int,
               head_dim: int, batch: int = 1) -> float:
    """Matmul FLOPs of one attention call (2·per MAC), per the mode's
    *expressed* computation (matches what cost_analysis sees for the jnp
    path, up to softmax)."""
    per_pair = 4.0 * head_dim  # QK^T + PV, 2 FLOPs per MAC each
    if mode.kind == "full":
        pairs = Sq * Skv
    elif mode.kind == "window":
        pairs = Sq * min(mode.local + 512, Skv)
    elif mode.kind == "streaming":
        pairs = Sq * min(mode.sink + mode.local + 512, Skv)
    elif mode.kind == "triangle":
        last = min(mode.chunk, Sq)
        pre = Sq - last
        pairs = pre * min(mode.sink + mode.local + 512, Skv) + last * Skv
    elif mode.kind == "block_topk":
        nkb = -(-Skv // mode.block)
        keep = xa_keep_blocks(nkb, mode.threshold)
        pairs = Sq * keep * mode.block
        # scoring cost
        pairs += (Sq // mode.stride) * (Skv // mode.stride)
    else:
        raise ValueError(mode.kind)
    return batch * num_heads * pairs * per_pair
