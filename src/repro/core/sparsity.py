"""Sparsity metrics and the Lagrangian training objective (paper §2.3, §3.2).

Ω_MSR (Eq. 3) — fraction of (layer, head) slots running SA.  With
layer-level routing every head in a layer shares the decision, so the
model-level ratio reduces to the SA fraction over routed layers.

Constraint (Eq. 6): per task type, L_diff = E[1 - r_soft] - t, penalized
by λ1·L_diff + λ2·L_diff² with **trainable** multipliers λ1, λ2 ≥ 0
updated by gradient *ascent* (sign-flipped in the optimizer; see
repro.train.optimizer).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FluxConfig

# Task-type ids for the Lagrangian (paper trains per-task multipliers).
TASK_RETRIEVAL = 0
TASK_HOLISTIC = 1


def msr(r_hard: jax.Array) -> jax.Array:
    """Model Sparsity Ratio over routed layers.

    r_hard: (..., num_routed_layers) with 1 = FA, 0 = SA.
    """
    return jnp.mean(1.0 - r_hard.astype(jnp.float32), axis=-1)


def lagrangian_init(flux: FluxConfig, key=None) -> Dict[str, jax.Array]:
    """λ1, λ2 per task type.  Paper: randomly initialized, then adapted
    by ascent.  The quadratic multiplier starts at a scale where the
    budget exerts visible pressure within a few hundred steps (the
    ascent keeps growing it while |L_diff| > 0)."""
    n = flux.num_task_types
    if key is not None:
        k1, k2 = jax.random.split(key)
        return {"lambda1": jax.random.uniform(k1, (n,), jnp.float32,
                                              0.0, 0.2),
                "lambda2": jax.random.uniform(k2, (n,), jnp.float32,
                                              0.2, 0.6)}
    return {"lambda1": jnp.full((n,), 0.1, jnp.float32),
            "lambda2": jnp.full((n,), 0.4, jnp.float32)}


def target_table(flux: FluxConfig) -> jax.Array:
    """Per-task sparse budget t (paper §4.1: retrieval 0.45, holistic 1.0)."""
    return jnp.array([flux.target_retrieval, flux.target_holistic],
                     jnp.float32)


def sparsity_loss(r_soft: jax.Array, task_type: jax.Array,
                  lagrange: Dict[str, jax.Array],
                  flux: FluxConfig) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Sparsity regularization term of Eq. 6.

    r_soft: (B, num_routed_layers) FA probabilities; task_type: (B,) int.
    Returns (scalar loss, diagnostics).  The λs enter the loss directly;
    the optimizer ascends on them (max_λ min_θ).
    """
    t = target_table(flux)[task_type]  # (B,)
    sparse_prob = jnp.mean(1.0 - r_soft, axis=-1)  # (B,) expected SA fraction
    # Per-task expectation E_X[1 - r_soft] - t, masked means per task type.
    n_types = flux.num_task_types
    onehot = jax.nn.one_hot(task_type, n_types, dtype=jnp.float32)  # (B, T)
    counts = jnp.maximum(onehot.sum(0), 1.0)
    per_task_sparse = (onehot * sparse_prob[:, None]).sum(0) / counts
    per_task_t = (onehot * t[:, None]).sum(0) / counts
    l_diff = per_task_sparse - per_task_t  # (T,)
    present = (onehot.sum(0) > 0).astype(jnp.float32)
    loss = jnp.sum(present * (lagrange["lambda1"] * l_diff
                              + lagrange["lambda2"] * jnp.square(l_diff)))
    diag = {"l_diff": l_diff, "per_task_sparsity": per_task_sparse,
            "present": present}
    return loss, diag


def project_lagrange(lagrange: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """Enforce λ ≥ 0 after the ascent step."""
    return {k: jnp.maximum(v, 0.0) for k, v in lagrange.items()}
