"""Static sparsity baselines the paper compares against (§2.2, §4.1):

  * TriangleMix [14]     — static *layer* pattern: deep layers sparse.
  * DuoAttention [43/44] — static *head* split: retrieval heads FA,
                           streaming heads sink+local.
  * PruLong [4]          — same mechanism class as DuoAttention here
                           (trained head masks); emulated with a
                           different head ordering (entropy-scored).
  * UnComp entropy [46]  — matrix-entropy layer ranking used in the
                           paper's §2.3 motivation study: lowest-entropy
                           layers are sparsified first.

All return either a per-layer pattern array (1=FA, 0=SA) or a routing
context for the model's ``("head_split", n_fa_kv)`` path.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# Layer-level static patterns
# ---------------------------------------------------------------------------

def static_pattern(cfg: ModelConfig, msr: float,
                   placement: str = "deep") -> np.ndarray:
    """(num_layers,) 1=FA / 0=SA with SA fraction ``msr`` over *routed*
    layers.  placement ∈ {"deep" (TriangleMix), "shallow",
    "interleave"}."""
    routed = list(cfg.routable_layers())
    n_sa = int(round(msr * len(routed)))
    pattern = np.ones((cfg.num_layers,), np.int32)
    if n_sa == 0:
        return pattern
    if placement == "deep":
        sa_layers = routed[-n_sa:]
    elif placement == "shallow":
        sa_layers = routed[:n_sa]
    elif placement == "interleave":
        idx = np.linspace(0, len(routed) - 1, n_sa).round().astype(int)
        sa_layers = [routed[i] for i in idx]
    else:
        raise ValueError(placement)
    pattern[list(sa_layers)] = 0
    return pattern


def trianglemix_pattern(cfg: ModelConfig, msr: float = 0.5) -> np.ndarray:
    """TriangleMix: shallow layers dense, deep layers triangle-sparse
    (use with flux.sa_mode="ta")."""
    return static_pattern(cfg, msr, "deep")


# ---------------------------------------------------------------------------
# UnComp matrix-entropy layer ranking (paper App. C)
# ---------------------------------------------------------------------------

def matrix_entropy(hidden: jax.Array, k_trunc: int = 32) -> jax.Array:
    """Truncated von Neumann entropy of the trace-normalized covariance.

    hidden (B, S, d) → scalar.  Eigenvalues of X·Xᵀ/tr come from the
    singular values of X.
    """
    B, S, d = hidden.shape
    x = hidden.reshape(B * S, d).astype(jnp.float32)
    x = x - x.mean(0, keepdims=True)
    s = jnp.linalg.svd(x, compute_uv=False)  # (min(BS, d),)
    lam = jnp.square(s)
    lam = lam / jnp.maximum(lam.sum(), 1e-12)
    k = min(k_trunc, lam.shape[0])
    top = jax.lax.top_k(lam, k)[0]
    return -jnp.sum(top * jnp.log(top + 1e-12))


def entropy_scores(params, cfg: ModelConfig, tokens: jax.Array,
                   k_trunc: int = 32, **fwd_kw) -> np.ndarray:
    """Per-layer entropy E_ℓ over a probe batch (paper Eq. 7)."""
    from repro.models import model as MD

    hs = MD.capture_hidden(params, cfg, tokens, **fwd_kw)  # (L, B, S, d)
    return np.asarray(
        jnp.stack([matrix_entropy(hs[i], k_trunc)
                   for i in range(hs.shape[0])]))


def entropy_pattern(cfg: ModelConfig, scores: Sequence[float],
                    msr: float) -> np.ndarray:
    """Progressive sparsification (paper App. C.2): keep the
    k = ⌊(1-Ω)·L⌋ highest-entropy routed layers as FA."""
    routed = list(cfg.routable_layers())
    sc = np.asarray([scores[i] for i in routed])
    k_keep = int((1.0 - msr) * len(routed))
    order = np.argsort(-sc)  # descending entropy
    pattern = np.zeros((cfg.num_layers,), np.int32)
    for i, kind in enumerate(cfg.layer_kinds):
        if kind != "attn":
            pattern[i] = 1
    for j in order[:k_keep]:
        pattern[routed[j]] = 1
    return pattern


# ---------------------------------------------------------------------------
# Head-level baselines (DuoAttention / PruLong)
# ---------------------------------------------------------------------------

def duo_n_fa_kv(cfg: ModelConfig, msr: float = 0.5) -> int:
    """Retrieval KV-head count for a target head sparsity."""
    return max(1, int(round((1.0 - msr) * cfg.num_kv_heads)))
