from repro.core import modes, router, sparsity  # noqa: F401
