"""Committed shardings for the pooled serving path (DESIGN.md
§Distributed serving).

Tensor-parallel pooled decode shards the KV *head* axis over the mesh
"model" axis and lets GSPMD propagate: every shard owns complete
softmax rows for its head subset, so attention over the cache needs no
collective at all — the only per-layer collectives are the tiny
activation combines at the head-sharded projections (the all-reduce of
the row-parallel ``wo`` contraction, O(d_model) per token).  This is
the committed-sharding expression of the ``lse_combine_decode``
flash-decoding idea: head sharding makes the LSE combine degenerate
(each shard's softmax is already exact for its heads), keeping the
collective O(H·D)-small while the cache never moves.

What shards and what replicates:

  * FullKV / RingKV ``k``/``v`` (slots, Hkv, S, D) — "model" on the
    Hkv dim when divisible; the slot axis stays unsharded (slot pools
    batch *requests*, and per-slot admission writes must stay local).
  * MLA LatentKV / RingLatentKV — REPLICATED.  The latent ``ckv`` has
    no per-head axis: its R dim is the *contraction* dim of the score
    einsum, so sharding it would all-reduce O(S)-sized scores every
    step — exactly the cache-scale collective this layout exists to
    avoid.  MLA still gets tensor parallelism from its head-sharded
    absorbed projections (``w_ukv`` is row-parallel in
    launch/shardings.py); only the cache is kept whole.
  * MambaCache ``h``/``conv_tail`` — REPLICATED (conv/ssm state mixes
    channels; the state is small and per-slot).
  * All bookkeeping (``positions``, ``length``) and the pool's
    ``logits``/``pos`` — REPLICATED.  The scheduler reads these on the
    host every tick; replication keeps those reads collective-free and
    keeps admission/retire bookkeeping identical to the single-device
    path.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# Cache fields that carry a (slots, Hkv, S, D) layout — the only ones
# head-shardable.  Everything else replicates (module docstring).
_HEAD_SHARDED_FIELDS = frozenset({"k", "v"})


def mesh_signature(mesh: Optional[Mesh]) -> Optional[Tuple]:
    """Hashable mesh identity for executable-guard keys.

    Committed shardings split jit cache entries per mesh, so the
    engine's O(#geometries) guard must count per-(geometry, mesh):
    this is the mesh half of that key.  None ⇒ the single-device path
    (uncommitted inputs), preserved as a distinct bucket."""
    if mesh is None:
        return None
    return tuple((name, int(mesh.shape[name])) for name in mesh.axis_names)


def _model_size(mesh: Mesh) -> int:
    return int(mesh.shape.get("model", 1))


def pool_cache_specs(caches: Any, mesh: Mesh):
    """NamedSharding tree for a slot-pool decode-cache list.

    Accepts concrete caches or an ``eval_shape`` spec — only shapes,
    dtypes and pytree paths are read."""
    model = _model_size(mesh)

    def assign(path, leaf):
        name = getattr(path[-1], "name", None) if path else None
        shp = tuple(leaf.shape)
        if (name in _HEAD_SHARDED_FIELDS and len(shp) == 4
                and shp[1] % model == 0 and shp[1] >= model):
            return NamedSharding(mesh, P(None, "model", None, None))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(assign, caches)


def shard_pool_caches(caches: Any, mesh: Mesh):
    """Commit a cache list to its pool shardings (device_put)."""
    return jax.device_put(caches, pool_cache_specs(caches, mesh))


def replicate(tree: Any, mesh: Mesh):
    """Commit a pytree to the replicated sharding (bookkeeping/logits)."""
    return jax.device_put(
        tree, jax.tree.map(lambda _: NamedSharding(mesh, P()), tree))
