from repro.distributed.sharding import constrain, logical_rules  # noqa: F401
from repro.distributed.pool_sharding import (  # noqa: F401
    mesh_signature, pool_cache_specs, replicate, shard_pool_caches)
