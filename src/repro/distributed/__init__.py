from repro.distributed.sharding import constrain, logical_rules  # noqa: F401
