"""Mesh-agnostic sharding hints.

Model code calls ``constrain(x, *axes)`` with *logical* axis names; the
launch layer activates a mesh (``jax.sharding.use_mesh``) and a logical→
mesh-axis mapping (``logical_rules``).  Outside any mesh context the
hints are no-ops, so unit tests and CPU smoke runs are unaffected.

Divisibility is checked per dimension against the live (abstract) mesh:
axes that do not evenly divide a dim are dropped (e.g. 8 KV heads on a
16-way "model" axis → replicated KV, the standard GQA-TP fallback).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

_state = threading.local()


def _rules() -> Optional[Dict[str, Optional[Tuple[str, ...]]]]:
    return getattr(_state, "rules", None)


@contextmanager
def logical_rules(rules: Dict[str, Optional[Tuple[str, ...]]]):
    """Activate a logical→mesh axis mapping (launch layer only)."""
    prev = _rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def _live_mesh():
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    try:
        if get_abstract is not None:
            mesh = get_abstract()
        else:  # jax 0.4/0.5: the legacy ``with mesh:`` ambient mesh
            from jax.interpreters import pxla
            mesh = pxla.thread_resources.env.physical_mesh
    except Exception:
        return None
    if mesh is None or getattr(mesh, "empty", True) or not mesh.axis_names:
        return None
    return mesh


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names; no-op without an
    active mesh context or rule set."""
    rules = _rules()
    if rules is None:
        return x
    mesh = _live_mesh()
    if mesh is None:
        return x
    spec = []
    used: set = set()  # a mesh axis may shard at most one dim
    for dim, a in zip(x.shape, axes):
        mapped = rules.get(a) if a is not None else None
        if mapped is None:
            spec.append(None)
            continue
        usable = [ax for ax in mapped
                  if ax in mesh.axis_names and ax not in used]
        total = int(np.prod([mesh.shape[ax] for ax in usable])) if usable \
            else 0
        if usable and total and dim % total == 0 and dim >= total:
            used.update(usable)
            spec.append(tuple(usable) if len(usable) > 1 else usable[0])
            continue
        picked = None  # single-axis fallback
        for ax in usable:
            s = mesh.shape[ax]
            if dim % s == 0 and dim >= s:
                picked = ax
                used.add(ax)
                break
        spec.append(picked)
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, TypeError):
        return x
