"""Distributed flash-decode: attention over sequence-sharded KV.

For ``long_500k`` (batch=1, 524288-token cache) the batch axis cannot
cover the mesh, so the baseline shards the KV *sequence* dim and lets
SPMD insert collectives — XLA materializes an all-gather of the whole
cache per step (gigabytes over ICI).  The production fix, standard in
TPU serving stacks, is flash-decoding across chips: every chip attends
over its local KV shard, then the shards' partial results merge with a
log-sum-exp combine — the collective shrinks from O(S·D) to O(H·D)
per layer (a few KB).

This is a *beyond-paper* optimization (EXPERIMENTS.md §Perf): the
paper's layer routing decides WHICH cache a layer reads; this decides
HOW a full cache is read at 500K.
"""
from __future__ import annotations

from functools import partial
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.6 top-level export
    from jax import shard_map as _shard_map
except ImportError:  # jax 0.4/0.5
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    """Version-compat wrapper: the replication-check kwarg was renamed
    ``check_rep`` → ``check_vma`` across jax versions."""
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=check_vma)
    except TypeError:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)


def lse_combine_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                       valid: jax.Array, mesh, kv_axes: Tuple[str, ...],
                       scale: Optional[float] = None) -> jax.Array:
    """q (B,Hq,1,D) replicated; k/v (B,Hkv,S,D) sharded over ``kv_axes``
    on the sequence dim; valid (S,) likewise sharded.  Returns the
    exact softmax attention output (B,Hq,1,D)."""
    B, Hq, _, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    scale = D ** -0.5 if scale is None else scale
    axes = kv_axes if len(kv_axes) > 1 else kv_axes[0]

    def local(qb, kb, vb, validb):
        # qb (B,Hq,1,D); kb/vb (B,Hkv,S_loc,D); validb (S_loc,)
        q5 = qb.reshape(B, Hkv, G, 1, D)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", q5, kb,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(validb[None, None, None, None, :], s, -1e30)
        m_loc = s.max(-1, keepdims=True)                    # (B,K,G,1,1)
        m_glob = lax.pmax(m_loc, axes)
        p = jnp.exp(s - m_glob)
        l_loc = p.sum(-1, keepdims=True)
        o_loc = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(vb.dtype), vb,
                           preferred_element_type=jnp.float32)
        l_glob = lax.psum(l_loc, axes)                      # O(1) bytes
        o_glob = lax.psum(o_loc, axes)                      # O(H·D) bytes
        out = o_glob / jnp.maximum(l_glob, 1e-20)
        return out.reshape(B, Hq, 1, D).astype(qb.dtype)

    kv_spec = P(None, None, axes, None)
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(), kv_spec, kv_spec, P(axes)),
        out_specs=P(),
        check_vma=False,
    )(q, k, v, valid)


def _flat_axis_index(kv_axes: Tuple[str, ...]):
    """Row-major flat shard index over possibly-multiple mesh axes.
    ``psum(1, axis)`` is the axis size on every jax version
    (``lax.axis_size`` only exists on newer releases)."""
    idx = lax.axis_index(kv_axes[0])
    for a in kv_axes[1:]:
        idx = idx * lax.psum(1, a) + lax.axis_index(a)
    return idx


def sharded_seq_insert(cache_k: jax.Array, cache_v: jax.Array,
                       k_new: jax.Array, v_new: jax.Array, pos,
                       mesh, kv_axes: Tuple[str, ...]):
    """Insert one token into a sequence-sharded KV cache without
    gathering it.

    A plain ``dynamic_update_slice`` at a traced position forces SPMD
    to all-gather the whole cache (observed: 19.3 GB/step for
    command-r at 500K — EXPERIMENTS.md §Perf); here every shard decides
    locally whether the position falls inside its slice and updates in
    place.  cache (B,Hkv,S,D) sharded over ``kv_axes`` on dim 2;
    k_new/v_new (B,Hkv,1,D) replicated."""
    axes = kv_axes if len(kv_axes) > 1 else kv_axes[0]

    def local(ck, cv, kn, vn, p):
        shard_len = ck.shape[2]
        idx = _flat_axis_index(kv_axes)
        start = idx * shard_len
        local_pos = jnp.clip(p - start, 0, shard_len - 1)
        mine = (p >= start) & (p < start + shard_len)
        ck_upd = lax.dynamic_update_slice_in_dim(ck, kn, local_pos, 2)
        cv_upd = lax.dynamic_update_slice_in_dim(cv, vn, local_pos, 2)
        return (jnp.where(mine, ck_upd, ck), jnp.where(mine, cv_upd, cv))

    kv_spec = P(None, None, axes, None)
    return shard_map(
        local, mesh=mesh,
        in_specs=(kv_spec, kv_spec, P(), P(), P()),
        out_specs=(kv_spec, kv_spec),
        check_vma=False,
    )(cache_k, cache_v, k_new, v_new, jnp.asarray(pos, jnp.int32))


def make_distributed_insert(mesh, kv_axes: Tuple[str, ...],
                            min_seq: int = 8192):
    """Adapter for ``repro.models.model.use_cache_insert``."""
    def fn(cache_k, cache_v, k_new, v_new, pos):
        if cache_k.shape[2] < min_seq:
            return None
        return sharded_seq_insert(cache_k, cache_v, k_new, v_new, pos,
                                  mesh, kv_axes)
    return fn


def make_distributed_dot_decode(mesh, kv_axes: Tuple[str, ...],
                                min_seq: int = 8192):
    """Adapter matching ``repro.models.model._dot_decode``'s signature,
    installed via ``model.use_decode_attn`` by the launch layer.
    Declines (returns None) for short caches — ring buffers stay on the
    local path — and for any non-shared mask (``valid.ndim != 1``,
    which includes pooled per-slot validity: slot pools batch short
    requests, the opposite regime from sequence-sharded 500K).

    Speaks the same trace protocol as
    ``kernels.decode_attention.make_kernel_decode_attn``: every
    accept/decline decision lands in ``fn.trace_log`` as ``(event,
    reason)`` with the engine's closed decline vocabulary ("min_len",
    "mask_rank"), so the kernel-decision replay and the
    ``decode_kernel_{hit,decline}`` counters cover the distributed
    path identically."""
    trace_log: List[Tuple[str, str]] = []

    def _note(event: str, reason: str) -> None:
        trace_log.append((event, reason))

    def fn(q, k, v, valid, scale=None):
        if valid.ndim != 1 or k.shape[2] < min_seq:
            _note("decline",
                  "mask_rank" if valid.ndim != 1 else "min_len")
            return None
        out = lse_combine_decode(q, k, v, valid, mesh, kv_axes,
                                 scale=scale)
        _note("hit", "lse_combine")
        return out

    def drain_log() -> List[Tuple[str, str]]:
        out = list(trace_log)
        trace_log.clear()
        return out

    fn.supports_pooled = False
    fn.supports_scale = True
    fn.trace_log = trace_log
    fn.drain_log = drain_log
    fn.min_len = min_seq
    return fn
