from repro.data.synthetic import (  # noqa: F401
    Batch,
    SyntheticTasks,
    mixture_iterator,
    retrieval_accuracy,
)
