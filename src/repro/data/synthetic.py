"""Synthetic task-labeled long-context corpus.

The paper trains the router on a mixture of retrieval-intensive (QA,
multi-hop) and context-holistic (LM, summarization, code) tasks
(§4.1).  We reproduce the *property that matters for the router* —
divergent sparsity tolerance — with two controlled synthetic families:

  * ``needle`` / ``multihop`` (retrieval-intensive): KEY/VALUE records
    are scattered through filler; the final query asks for a key's
    value.  Answering requires exact long-range attention — accuracy
    collapses under sink+local sparsity once the needle falls outside
    the window (paper Fig. 1a).
  * ``markov`` (context-holistic): a fixed-order Markov language; next-
    token prediction depends only on recent context — robust to
    aggressive sparsification.

Token space layout (vocab ≥ 64):
  0 PAD, 1 QUERY, 2 KEY, 3 VALUE, 4 SEP,
  [5, 5+n_symbols) symbol tokens (keys/values/filler/markov states).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.core.sparsity import TASK_HOLISTIC, TASK_RETRIEVAL

PAD, QUERY, KEY, VALUE, SEP = 0, 1, 2, 3, 4
SYM0 = 5


@dataclass
class Batch:
    tokens: np.ndarray      # (B, S) int32
    labels: np.ndarray      # (B, S) int32 (next-token targets)
    loss_mask: np.ndarray   # (B, S) float32
    task_type: np.ndarray   # (B,) int32


def _n_symbols(vocab: int) -> int:
    return max(8, min(vocab - SYM0, 256))


def _markov_matrix(rng: np.random.Generator, n: int) -> np.ndarray:
    """Sparse-ish row-stochastic transition matrix (top-4 successors)."""
    m = np.full((n, n), 1e-3)
    for i in range(n):
        succ = rng.choice(n, size=4, replace=False)
        m[i, succ] += rng.dirichlet(np.ones(4)) * 10.0
    return m / m.sum(1, keepdims=True)


class SyntheticTasks:
    """Deterministic-seeded generator for both task families."""

    def __init__(self, vocab: int, seed: int = 0):
        self.vocab = vocab
        self.n_sym = _n_symbols(vocab)
        rng = np.random.default_rng(seed)
        self.markov = _markov_matrix(rng, self.n_sym)

    # -- context-holistic ---------------------------------------------------
    def markov_batch(self, rng: np.random.Generator, batch: int,
                     seq: int) -> Batch:
        toks = np.empty((batch, seq + 1), np.int64)
        toks[:, 0] = rng.integers(0, self.n_sym, batch)
        cdf = np.cumsum(self.markov, axis=1)
        for t in range(seq):
            u = rng.random(batch)
            toks[:, t + 1] = (u[:, None] < cdf[toks[:, t]]).argmax(1)
        toks += SYM0
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        mask = np.ones((batch, seq), np.float32)
        return Batch(tokens, labels, mask,
                     np.full(batch, TASK_HOLISTIC, np.int32))

    # -- retrieval-intensive ------------------------------------------------
    # Symbol space is split: filler draws from the lower half, keys and
    # values from the upper half — filler can never collide with a key,
    # so the retrieval target is unambiguous (otherwise a random filler
    # token equal to the key caps attainable accuracy).
    @property
    def _kv_pool(self) -> Tuple[int, int]:
        half = self.n_sym // 2
        return SYM0 + half, SYM0 + self.n_sym

    def _filler(self, rng, shape):
        half = self.n_sym // 2
        return rng.integers(SYM0, SYM0 + half, shape).astype(np.int64)

    def needle_batch(self, rng: np.random.Generator, batch: int, seq: int,
                     hops: int = 1, needle_pos: Optional[float] = None
                     ) -> Batch:
        """(KEY, k, v, SEP) records in filler; suffix (SEP, QUERY, k) →
        predict v at the final position (value right after the matched
        key: the classic induction pattern, learnable by a 2-layer
        model).

        ``needle_pos`` ∈ [0,1) pins the needle's relative depth
        (RULER-style placement sweeps); None = uniform random.
        """
        lo_kv, hi_kv = self._kv_pool
        tokens = self._filler(rng, (batch, seq))
        labels = np.zeros((batch, seq), np.int64)
        mask = np.zeros((batch, seq), np.float32)
        rec, q_len = 4, 3
        for b in range(batch):
            k, v = rng.choice(np.arange(lo_kv, hi_kv), size=2,
                              replace=False)
            lo, hi = 0, seq - q_len - rec - 1
            if needle_pos is not None:
                p = int(needle_pos * (hi - lo)) + lo
            else:
                p = int(rng.integers(lo, max(hi, lo + 1)))
            tokens[b, p:p + rec] = (KEY, k, v, SEP)
            tokens[b, seq - q_len:] = (SEP, QUERY, k)
            labels[b, seq - 1] = v
            mask[b, seq - 1] = 1.0
        return Batch(tokens.astype(np.int32), labels.astype(np.int32), mask,
                     np.full(batch, TASK_RETRIEVAL, np.int32))

    def multihop_batch(self, rng, batch: int, seq: int) -> Batch:
        """Two-hop retrieval: (KEY,k0,k1,SEP) … (KEY,k1,k2,SEP); query
        k0 → k2 requires composing two lookups (MuSiQue-style)."""
        lo_kv, hi_kv = self._kv_pool
        tokens = self._filler(rng, (batch, seq))
        labels = np.zeros((batch, seq), np.int64)
        mask = np.zeros((batch, seq), np.float32)
        for b in range(batch):
            k0, k1, k2 = rng.choice(np.arange(lo_kv, hi_kv), size=3,
                                    replace=False)
            hi = seq - 3 - 5
            p0, p1 = sorted(rng.choice(hi - 8, size=2, replace=False))
            p1 += 8  # ensure no overlap
            tokens[b, p0:p0 + 4] = (KEY, k0, k1, SEP)
            tokens[b, p1:p1 + 4] = (KEY, k1, k2, SEP)
            tokens[b, seq - 3:] = (SEP, QUERY, k0)
            labels[b, seq - 1] = k2
            mask[b, seq - 1] = 1.0
        return Batch(tokens.astype(np.int32), labels.astype(np.int32), mask,
                     np.full(batch, TASK_RETRIEVAL, np.int32))

    def batch(self, rng, task: str, batch: int, seq: int, **kw) -> Batch:
        if task == "markov":
            return self.markov_batch(rng, batch, seq)
        if task == "needle":
            return self.needle_batch(rng, batch, seq, **kw)
        if task == "multihop":
            return self.multihop_batch(rng, batch, seq)
        raise ValueError(task)


def mixture_iterator(vocab: int, batch: int, seq: int, *, seed: int = 0,
                     weights: Optional[Dict[str, float]] = None
                     ) -> Iterator[Batch]:
    """Infinite task-mixture stream (paper §4.1 / Fig. 7).

    ``weights``: task → sampling weight; default balanced
    retrieval/holistic (the paper shows skew collapses the router —
    bench_data_balance sweeps this).
    """
    weights = weights or {"markov": 0.5, "needle": 0.35, "multihop": 0.15}
    tasks = list(weights)
    p = np.asarray([weights[t] for t in tasks], np.float64)
    p /= p.sum()
    gen = SyntheticTasks(vocab, seed)
    rng = np.random.default_rng(seed + 1)
    while True:
        yield gen.batch(rng, tasks[rng.choice(len(tasks), p=p)], batch, seq)


def retrieval_accuracy(logits: np.ndarray, batch: Batch) -> float:
    """Accuracy at masked (answer) positions."""
    pred = logits.argmax(-1)
    hit = (pred == batch.labels) * (batch.loss_mask > 0)
    denom = batch.loss_mask.sum()
    return float(hit.sum() / max(denom, 1))
