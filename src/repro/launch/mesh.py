"""Production mesh construction.

Target hardware: TPU v5e pods — 16×16 = 256 chips per pod; the
multi-pod configuration spans 2 pods = 512 chips with a leading "pod"
axis (DCN between pods, ICI within).

A FUNCTION, not a module constant: importing this module must never
touch jax device state (the dry-run pins the device count before any
jax initialization).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def mesh_context(mesh: Mesh):
    """Context manager activating ``mesh`` for sharding constraints:
    ``jax.set_mesh`` on jax >= 0.6, the legacy ``with mesh:`` context
    on 0.4/0.5."""
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


def _make_mesh(shape, axes, devices) -> Mesh:
    """``jax.make_mesh`` with explicit Auto axis types where the jax
    version has them (>= 0.5); older versions only have Auto axes."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, devices=devices,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes, devices=devices)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import (see launch/dryrun.py)")
    return _make_mesh(shape, axes, devices[:n])


def make_debug_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Tiny mesh over the real local devices (tests)."""
    n = data * model
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {(data, model)}, have "
            f"{len(devices)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
            "before any jax import (see launch/dryrun.py)")
    return _make_mesh((data, model), ("data", "model"), devices[:n])


# Hardware constants (TPU v5e) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
