"""Production mesh construction.

Target hardware: TPU v5e pods — 16×16 = 256 chips per pod; the
multi-pod configuration spans 2 pods = 512 chips with a leading "pod"
axis (DCN between pods, ICI within).

A FUNCTION, not a module constant: importing this module must never
touch jax device state (the dry-run pins the device count before any
jax initialization).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import (see launch/dryrun.py)")
    auto = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, devices=devices[:n],
                         axis_types=auto)


def make_debug_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Tiny mesh over the real local devices (tests)."""
    n = data * model
    auto = (jax.sharding.AxisType.Auto, jax.sharding.AxisType.Auto)
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[:n], axis_types=auto)


# Hardware constants (TPU v5e) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
