import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
for the production meshes, prove memory/sharding coherence, and record
the roofline source numbers (EXPERIMENTS.md §Dry-run).

The two lines above MUST precede any jax import — jax locks the device
count at first init.  Run:

    PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-mini-3.8b \
        --shape decode_32k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all   # the full matrix
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import ALL_ARCHS, SHAPES, get_config  # noqa: E402
from repro.distributed import logical_rules  # noqa: E402
from repro.launch import hlo_analysis as HA  # noqa: E402
from repro.launch import workloads as WL  # noqa: E402
from repro.launch.mesh import (make_production_mesh,  # noqa: E402
                                mesh_context)

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "dryrun")


def run_one(arch: str, shape_name: str, multi_pod: bool,
            out_dir: str = ARTIFACTS, save_hlo: bool = False,
            variant: str = "", causal_split: int = 0, **wl_kw) -> dict:
    cfg = get_config(arch)
    if causal_split:
        cfg = cfg.replace(causal_split_depth=causal_split)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape)
        + (f"_{variant}" if variant else ""),
        "n_chips": int(n_chips), "ok": False,
    }
    t0 = time.time()
    try:
        wl = WL.build_workload(cfg, shape, mesh, **wl_kw)
        record["workload"] = wl.name
        with mesh_context(mesh), logical_rules(wl.rules):
            lowered = jax.jit(wl.fn, in_shardings=wl.in_shardings).lower(
                *wl.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            hlo = compiled.as_text()
        record["t_lower_s"] = round(t_lower, 1)
        record["t_compile_s"] = round(t_compile, 1)
        record["memory"] = HA.memory_summary(compiled)
        record["roofline"] = HA.roofline_terms(
            compiled, hlo, n_chips, wl.model_flops,
            memory=record["memory"])
        record["ok"] = True
        if save_hlo:
            os.makedirs(out_dir, exist_ok=True)
            with open(os.path.join(
                    out_dir, f"{arch}_{shape_name}"
                    f"_{record['mesh']}.hlo.txt"), "w") as f:
                f.write(hlo)
    except Exception as e:  # noqa: BLE001 — recorded, not swallowed
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir,
                        f"{arch}_{shape_name}_{record['mesh']}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=2, default=str)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="full (arch × shape) matrix on this mesh")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default=ARTIFACTS)
    ap.add_argument("--distributed-kv", action="store_true",
                    help="shard_map LSE-combine decode over "
                         "sequence-sharded KV (§Perf optimized variant)")
    ap.add_argument("--decode-msr", type=float, default=0.5)
    ap.add_argument("--decode-tp", action="store_true",
                    help="serving-style full-TP weight sharding "
                         "(no per-step FSDP weight gathers; §Perf)")
    ap.add_argument("--causal-split", type=int, default=0,
                    help="recursive causal split depth for expressed-"
                         "FLOP reduction (§Perf optimized variant)")
    ap.add_argument("--no-seq-shard", action="store_true",
                    help="train ablation: replicate the residual stream "
                         "instead of Megatron-SP seq sharding")
    args = ap.parse_args()

    pairs = ([(a, s) for a in ALL_ARCHS for s in SHAPES]
             if args.all else [(args.arch, args.shape)])
    n_ok = 0
    for arch, shape in pairs:
        wl_kw = {}
        variant = ""
        if SHAPES[shape].kind == "decode":
            if args.distributed_kv:
                wl_kw["distributed_kv"] = True
                variant = "distkv"
            if args.decode_tp:
                wl_kw["decode_tp"] = True
                variant = (variant + "_tp").strip("_")
            if args.decode_msr != 0.5:
                wl_kw["msr"] = args.decode_msr
                variant = (variant + f"_msr{args.decode_msr}").strip("_")
        elif args.causal_split:
            variant = f"csplit{args.causal_split}"
        if SHAPES[shape].kind == "train" and args.no_seq_shard:
            wl_kw["seq_shard"] = False
            variant = (variant + "_noseqshard").strip("_")
        r = run_one(arch, shape, args.multi_pod, args.out,
                    save_hlo=args.save_hlo, variant=variant,
                    causal_split=args.causal_split, **wl_kw)
        status = "OK " if r["ok"] else "FAIL"
        extra = ""
        if r["ok"]:
            rl = r["roofline"]
            extra = (f"compute={rl['t_compute_s']:.3e}s "
                     f"mem={rl['t_memory_s']:.3e}s "
                     f"coll={rl['t_collective_s']:.3e}s "
                     f"bottleneck={rl['bottleneck']}")
        else:
            extra = r.get("error", "")[:160]
        print(f"[{status}] {arch:24s} {shape:12s} mesh={r['mesh']:10s} "
              f"{extra}", flush=True)
        n_ok += r["ok"]
    print(f"{n_ok}/{len(pairs)} passed")
    if n_ok != len(pairs):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
