"""Sharding assignment for the production mesh.

Strategy (DESIGN.md §5):
  * Weights — 2-D sharded: one dim on "model" (tensor parallel), the
    largest remaining divisible dim on ("pod","data") (FSDP).  Stacked
    trunk leaves skip their leading n_periods axis.
  * Batch activations — batch over ("pod","data").
  * Sequence ("seq" logical axis) — "model" during training/prefill
    (Megatron-SP-style residual sharding: the scan-saved activations
    are the memory driver at 100B scale).
  * Decode KV caches — batch over data; when the batch axis can't
    cover the mesh (long_500k, B=1) the *sequence* dim shards over
    ("data","model") and attention runs over sequence-sharded KV
    (baseline lets SPMD place collectives; the shard_map LSE-combine
    decode in repro/distributed/decode.py is the optimized path).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return fsdp_axes(mesh)


def axes_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def _spec_tuple(t: Tuple[str, ...]):
    return t if len(t) > 1 else (t[0] if t else None)


def param_spec(shape: Tuple[int, ...], mesh: Mesh,
               skip_leading: int = 0) -> P:
    """Model-then-FSDP 2-D weight sharding by divisibility."""
    model = mesh.shape.get("model", 1)
    fs = fsdp_axes(mesh)
    fs_size = axes_size(mesh, fs)
    spec: list = [None] * len(shape)
    dims = list(range(skip_leading, len(shape)))
    # 'model' on the last divisible dim (output features / heads / ffn)
    model_dim = None
    for d in reversed(dims):
        if shape[d] % model == 0 and shape[d] >= model:
            model_dim = d
            spec[d] = "model"
            break
    # FSDP on the largest remaining divisible dim
    rest = [d for d in dims if d != model_dim]
    rest.sort(key=lambda d: -shape[d])
    for d in rest:
        if shape[d] % fs_size == 0 and shape[d] >= fs_size:
            spec[d] = _spec_tuple(fs)
            break
    return P(*spec)


def param_shardings(params_spec, mesh: Mesh):
    """NamedSharding tree for the params pytree (eval_shape output)."""
    def assign(path, leaf):
        stacked = any(getattr(p, "key", None) in ("trunk", "layers")
                      for p in path)
        skip = 1 if stacked else 0
        if len(leaf.shape) <= skip:  # scalars / stacked scalars
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, param_spec(leaf.shape, mesh, skip))

    return jax.tree_util.tree_map_with_path(assign, params_spec)


# Megatron-style row-parallel weights: output/down projections contract
# over the sharded dim, producing partial sums that all-reduce — the
# activation (B,1,d) is tiny at decode.  Everything else is
# column-parallel (output-dim sharded).
ROW_PARALLEL_NAMES = frozenset({"wo", "down", "out_proj", "w_ukv"})
# Attention projections must stay head-aligned: TP over "model" only
# (a full-mesh split would shard inside head_dim and un-localize the
# attention math — observed 6× collective blow-up at B=1).
ATTN_PARAM_NAMES = frozenset({"wq", "wk", "wv", "wo", "w_dq", "w_uq",
                              "w_dkv", "w_kr", "w_ukv", "in_proj",
                              "out_proj", "conv_w"})


def param_spec_decode_tp(shape: Tuple[int, ...], mesh: Mesh,
                         skip_leading: int = 0,
                         row_parallel: bool = False,
                         model_only: bool = False) -> P:
    """Serving-time weight sharding: full tensor-parallel over the WHOLE
    mesh on one dim, NO FSDP dim.

    FSDP weight sharding re-gathers every weight on every decode step
    (found in the baseline HLO: 2.5 GB of f32 weight all-gathers per
    step for phi3 — EXPERIMENTS.md §Perf).  With weights TP-sharded
    column-parallel (and down/out projections row-parallel so partial
    products all-reduce), the per-step collectives shrink to activation
    psums of (B,1,d)."""
    all_axes = tuple(a for a in ("pod", "data", "model")
                     if a in mesh.axis_names)
    spec: list = [None] * len(shape)
    dims = list(range(skip_leading, len(shape)))
    order = dims if row_parallel else list(reversed(dims))
    model_axes = ("model",) if "model" in mesh.axis_names else ()
    candidates = ((model_axes,) if model_only
                  else (all_axes, model_axes))
    for axes in candidates:
        if not axes:
            continue
        size = axes_size(mesh, tuple(axes))
        for d in order:
            if shape[d] % size == 0 and shape[d] >= size:
                spec[d] = _spec_tuple(tuple(axes))
                return P(*spec)
    return P(*spec)


def param_shardings_decode_tp(params_spec, mesh: Mesh):
    def assign(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "idx", "")))
                 for p in path]
        stacked = any(n in ("trunk", "layers") for n in names)
        skip = 1 if stacked else 0
        if len(leaf.shape) <= skip:
            return NamedSharding(mesh, P())
        row = bool(set(names) & ROW_PARALLEL_NAMES)
        attn = bool(set(names) & ATTN_PARAM_NAMES)
        return NamedSharding(
            mesh, param_spec_decode_tp(leaf.shape, mesh, skip,
                                       row_parallel=row,
                                       model_only=attn))

    return jax.tree_util.tree_map_with_path(assign, params_spec)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def batch_sharding(mesh: Mesh, shape: Tuple[int, ...]) -> NamedSharding:
    """(B, ...) arrays: batch over ("pod","data") when divisible."""
    ba = batch_axes(mesh)
    if shape and shape[0] % axes_size(mesh, ba) == 0 and shape[0] > 1:
        return NamedSharding(mesh, P(_spec_tuple(ba),
                                     *([None] * (len(shape) - 1))))
    return NamedSharding(mesh, P())


def cache_shardings(caches_spec, mesh: Mesh, batch: int):
    """Decode-cache tree: batch-sharded when possible; otherwise the
    long sequence dim shards over (data, model)."""
    ba = batch_axes(mesh)
    ba_size = axes_size(mesh, ba)
    model = mesh.shape.get("model", 1)
    seq_axes = tuple(a for a in ("data", "model") if a in mesh.axis_names)
    if "pod" in mesh.axis_names:
        seq_axes = ("pod",) + seq_axes
    seq_size = axes_size(mesh, seq_axes)

    def assign(path, leaf):
        shp = leaf.shape
        names = [getattr(p, "name", getattr(p, "key", "")) for p in path]
        field = names[-1] if names else ""
        if len(shp) == 0:
            return NamedSharding(mesh, P())
        spec: list = [None] * len(shp)
        batch_ok = shp[0] % ba_size == 0 and shp[0] >= ba_size
        if field in ("k", "v"):          # (B, Hkv, S, D)
            if batch_ok:
                spec[0] = _spec_tuple(ba)
                if shp[1] % model == 0:
                    spec[1] = "model"
                elif shp[2] % model == 0 and shp[2] >= 4096:
                    spec[2] = "model"    # seq-sharded KV
            elif shp[2] % seq_size == 0:
                spec[2] = _spec_tuple(seq_axes)
        elif field == "ckv":             # (B, S, R)
            if batch_ok:
                spec[0] = _spec_tuple(ba)
                if shp[1] % model == 0 and shp[1] >= 4096:
                    spec[1] = "model"
            elif shp[1] % seq_size == 0:
                spec[1] = _spec_tuple(seq_axes)
        elif field == "kr":              # (B, 1, S, rope)
            if batch_ok:
                spec[0] = _spec_tuple(ba)
            elif shp[2] % seq_size == 0:
                spec[2] = _spec_tuple(seq_axes)
        elif field == "h":               # mamba state (B, H, P, N)
            if batch_ok:
                spec[0] = _spec_tuple(ba)
            if shp[1] % model == 0:
                spec[1] = "model"
        elif field == "conv_tail":       # (B, W-1, C)
            if batch_ok:
                spec[0] = _spec_tuple(ba)
        # positions/length: replicated
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(assign, caches_spec)


# Logical-axis rules for repro.distributed.constrain, per workload kind.
TRAIN_RULES: Dict[str, Optional[Tuple[str, ...]]] = {
    "batch": ("pod", "data"),
    "seq": ("model",),      # Megatron-SP-style residual sharding
    "heads": ("model",),
    "kv_heads": ("model",),
    "ffn": ("model",),
    "expert": ("model",),
    "vocab": ("model",),
    "embed": None,
    "fsdp": ("pod", "data"),
}

PREFILL_RULES = dict(TRAIN_RULES)

DECODE_RULES = dict(TRAIN_RULES, seq=None)
