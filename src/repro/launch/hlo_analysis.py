"""Roofline-term extraction from lowered/compiled artifacts.

compute / memory terms come from ``compiled.cost_analysis()``;
collective bytes are NOT in cost_analysis — they are summed from the
post-SPMD HLO text (every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op's output bytes).

Hardware constants (TPU v5e): see repro.launch.mesh.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass
from typing import Dict, List, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %ag = bf16[2,16,128]{2,1,0} all-gather(%x), ...
#       %t = (f32[8,128]{1,0}, u32[]) all-reduce(...)
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|"
    r"collective-permute)\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    total_bytes: int
    by_kind: Dict[str, int]
    op_counts: Dict[str, int]


def collective_bytes(hlo_text: str) -> CollectiveStats:
    by_kind: Dict[str, int] = {}
    counts: Dict[str, int] = {}
    for m in _OP_RE.finditer(hlo_text):
        shape_text, kind = m.group(1), m.group(2)
        kind = kind.replace("-start", "")
        b = _shape_bytes(shape_text)
        by_kind[kind] = by_kind.get(kind, 0) + b
        counts[kind] = counts.get(kind, 0) + 1
    return CollectiveStats(total_bytes=sum(by_kind.values()),
                           by_kind=by_kind, op_counts=counts)


def _cost_dict(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def roofline_terms(compiled, hlo_text: str, n_chips: int,
                   model_flops: Optional[float] = None,
                   memory: Optional[Dict] = None) -> Dict:
    """The three roofline terms (§Roofline) in seconds, plus sources.

    All quantities are PER DEVICE (SPMD-partitioned module; calibrated
    against hand-counted sharded matmuls — EXPERIMENTS.md §Dry-run).

      compute    — loop-aware matmul FLOPs from the HLO walker
                   (``hlo_costs``): scan bodies × trip count,
                   lax.cond branches → max.  The raw
                   ``cost_analysis()`` numbers are kept under
                   ``*_xla_raw`` but they count loop bodies ONCE.
      memory     — per-step HBM traffic proxied by
                   argument+output+temp residency (every decode step
                   reads the caches & params once; temp ≈ activation
                   traffic).
      collective — loop-aware collective output bytes over ICI.
    """
    from repro.launch.hlo_costs import loop_aware_costs

    cost = _cost_dict(compiled)
    la = loop_aware_costs(hlo_text)
    coll_raw = collective_bytes(hlo_text)
    mem = memory or {}
    hbm_traffic = sum(mem.get(k) or 0 for k in
                      ("argument_size_in_bytes", "output_size_in_bytes",
                       "temp_size_in_bytes"))
    t_compute = la.flops / PEAK_FLOPS_BF16
    t_memory = hbm_traffic / HBM_BW
    t_collective = la.coll_bytes / ICI_BW
    terms = {
        "hlo_flops_per_chip": la.flops,
        "hbm_traffic_bytes_per_chip": hbm_traffic,
        "collective_bytes_per_chip": la.coll_bytes,
        "collective_by_kind": la.coll_by_kind,
        "collective_op_counts": coll_raw.op_counts,
        "flops_xla_raw": float(cost.get("flops", 0.0)),
        "bytes_xla_raw": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes_unrolled_once": coll_raw.total_bytes,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "n_chips": n_chips,
    }
    terms["bottleneck"] = max(
        ("compute", t_compute), ("memory", t_memory),
        ("collective", t_collective), key=lambda kv: kv[1])[0]
    if model_flops is not None:
        terms["model_flops"] = model_flops
        total = la.flops * n_chips
        terms["useful_flop_ratio"] = model_flops / total if total else None
    return terms


def memory_summary(compiled) -> Dict[str, Optional[int]]:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        out[k] = int(getattr(ma, k)) if ma is not None and hasattr(ma, k) \
            else None
    return out
