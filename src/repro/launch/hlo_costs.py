"""Loop-aware HLO cost analysis.

``compiled.cost_analysis()`` counts every computation ONCE — a
``lax.scan`` body's FLOPs are not multiplied by the trip count, and
both branches of a ``lax.cond`` are summed (calibrated in
EXPERIMENTS.md §Dry-run).  For a framework whose trunk is a scan over
layer periods and whose attention is a scan over query blocks, that
undercounts compute by orders of magnitude and silently miscounts
collectives inside the loop.

This module re-derives matmul FLOPs and collective bytes by walking the
post-optimization HLO text:

  * per-computation local costs (dot ops → 2·M·N·K; collective ops →
    output bytes),
  * ``fusion``/``call`` sites add the called computation's cost,
  * ``while`` sites multiply the body by the trip count inferred from
    the loop condition's compare-against-constant,
  * ``conditional`` sites take the MAX across branches (one branch
    executes at runtime — exactly the flux hard-routing semantics).

Elementwise FLOPs are ignored (dots dominate the compute roofline term
on the MXU); HBM traffic is taken from memory_analysis + the
analytical model instead (see hlo_analysis.roofline_terms).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "s4": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "u4": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

# header lines start at column 0: ``%name (args) -> type {`` — the arg
# list may contain nested parens (tuple types), so match loosely and
# require the trailing "{".
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\S+(?:\s*\([^)]*\))?)")
_CALLS = re.compile(r"(?:calls|to_apply)=%([\w.\-]+)")
_WHILE = re.compile(r"condition=%([\w.\-]+),\s*body=%([\w.\-]+)")
_COND_BRANCHES = re.compile(
    r"(?:true_computation=%([\w.\-]+),\s*false_computation=%([\w.\-]+)"
    r"|branch_computations=\{([^}]*)\})")
_CONST = re.compile(r"constant\((\d+)\)")
_DOT_DIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
# dot's lhs operand, in both HLO print dialects: untyped ``dot(%op, …)``
# and typed ``dot(f32[64,64]{1,0} %op, …)`` (the type carries the shape).
_DOT_LHS = re.compile(r" dot\((?:([a-z0-9]+)\[([0-9,]*)\]\S*\s+)?%([\w.\-]+)")
_OPERANDS = re.compile(r"\(([^)]*)\)")

_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")


def _parse_shape(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _parse_shape(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(text: str) -> int:
    shapes = _parse_shape(text)
    if not shapes:
        return 0
    n = 1
    for d in shapes[0][1]:
        n *= d
    return n


@dataclass
class Cost:
    flops: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.coll_bytes += o.coll_bytes
        for k, v in o.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.coll_bytes * f,
                    {k: v * f for k, v in self.coll_by_kind.items()})


@dataclass
class _Line:
    name: str
    result_type: str
    op_text: str


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[str]] = {}
        self.entry: Optional[str] = None
        self._split(hlo_text)
        self._memo: Dict[str, Cost] = {}

    # -- parsing -----------------------------------------------------------
    def _split(self, text: str) -> None:
        cur = None
        buf: List[str] = []
        for line in text.splitlines():
            m = _COMP_HDR.match(line)
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                if line.startswith("ENTRY"):
                    self.entry = cur
                buf = []
                self.computations[cur] = buf
            elif cur is not None:
                if line.startswith("}"):
                    cur = None
                else:
                    buf.append(line)

    # -- trip count --------------------------------------------------------
    def trip_count(self, cond_comp: str) -> int:
        """Largest integer constant in the loop condition (jax scans
        count 0..N-1 with an LT compare); 1 if none found."""
        best = 1
        seen = set()

        def walk(name):
            if name in seen or name not in self.computations:
                return
            seen.add(name)
            for line in self.computations[name]:
                for c in _CONST.findall(line):
                    best_local = int(c)
                    nonlocal best
                    best = max(best, best_local)
                for called in _CALLS.findall(line):
                    walk(called)

        walk(cond_comp)
        return best

    # -- per-line costs ------------------------------------------------------
    def _line_cost(self, line: str) -> Cost:
        c = Cost()
        m = _DEF.match(line)
        if not m:
            return c
        body = line[m.end(1):]
        # dot flops: 2 · prod(result dims) · K  (K = contracted size)
        if re.search(r"=\s*\S+\s+dot\(", line) or " dot(" in line:
            result_elems = _shape_elems(line.split("=", 1)[1])
            k = self._dot_contracted_size(line)
            c.flops += 2.0 * result_elems * k
        for kind in _COLLECTIVE_KINDS:
            if re.search(rf"\s{kind}(-start)?\(", line):
                b = _shape_bytes(line.split("=", 1)[1].split("(", 1)[0])
                c.coll_bytes += b
                c.coll_by_kind[kind] = c.coll_by_kind.get(kind, 0) + b
                break
        return c

    def _dot_contracted_size(self, line: str) -> int:
        m = _DOT_DIMS.search(line)
        if not m:
            return 1
        dims = [int(d) for d in m.group(1).split(",") if d]
        lhs = _DOT_LHS.search(line)
        shape = None
        if lhs is not None:
            if lhs.group(2) is not None:  # typed operand: shape inline
                shape = [int(d) for d in lhs.group(2).split(",") if d]
            else:                         # untyped: look up the def
                shape = self._operand_shapes.get(lhs.group(3))
        if shape is None:
            return 1
        k = 1
        for d in dims:
            if d < len(shape):
                k *= shape[d]
        return k

    # -- computation cost ----------------------------------------------------
    def computation_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Cost()  # cycle guard
        total = Cost()
        lines = self.computations.get(name, [])
        # operand shape env for dot contraction sizing
        self._operand_shapes = getattr(self, "_operand_shapes", {})
        for line in lines:
            m = _DEF.match(line)
            if m:
                shapes = _parse_shape(line.split("=", 1)[1].split("(")[0])
                if shapes:
                    self._operand_shapes[m.group(1)] = shapes[0][1]
        for line in lines:
            total += self._line_cost(line)
            w = _WHILE.search(line)
            if w and " while(" in line:
                cond, body = w.group(1), w.group(2)
                trips = self.trip_count(cond)
                total += self.computation_cost(body).scaled(trips)
                total += self.computation_cost(cond).scaled(trips)
                continue
            cb = _COND_BRANCHES.search(line)
            if cb and " conditional(" in line:
                branches = ([cb.group(1), cb.group(2)] if cb.group(1)
                            else [b.strip().lstrip("%") for b in
                                  cb.group(3).split(",")])
                costs = [self.computation_cost(b) for b in branches if b]
                if costs:
                    # one branch runs at runtime → max (hard routing)
                    best = max(costs, key=lambda x: x.flops)
                    total += best
                continue
            for called in _CALLS.findall(line):
                total += self.computation_cost(called)
        self._memo[name] = total
        return total

    def entry_cost(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.computation_cost(self.entry)


def loop_aware_costs(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).entry_cost()
