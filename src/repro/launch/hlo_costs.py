"""Loop-aware HLO cost analysis.

``compiled.cost_analysis()`` counts every computation ONCE — a
``lax.scan`` body's FLOPs are not multiplied by the trip count, and
both branches of a ``lax.cond`` are summed (calibrated in
EXPERIMENTS.md §Dry-run).  For a framework whose trunk is a scan over
layer periods and whose attention is a scan over query blocks, that
undercounts compute by orders of magnitude and silently miscounts
collectives inside the loop.

This module re-derives matmul FLOPs and collective bytes by walking the
post-optimization HLO text:

  * per-computation local costs (dot ops → 2·M·N·K; collective ops →
    output bytes),
  * ``fusion``/``call`` sites add the called computation's cost,
  * ``while`` sites multiply the body by the trip count inferred from
    the loop condition's compare-against-constant,
  * ``conditional`` sites take the MAX across branches (one branch
    executes at runtime — exactly the flux hard-routing semantics).

Elementwise FLOPs are ignored (dots dominate the compute roofline term
on the MXU); HBM traffic is taken from memory_analysis + the
analytical model instead (see hlo_analysis.roofline_terms).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "s4": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "u4": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

# header lines start at column 0: ``%name (args) -> type {`` — the arg
# list may contain nested parens (tuple types), so match loosely and
# require the trailing "{".
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\S+(?:\s*\([^)]*\))?)")
_CALLS = re.compile(r"(?:calls|to_apply)=%([\w.\-]+)")
_WHILE = re.compile(r"condition=%([\w.\-]+),\s*body=%([\w.\-]+)")
_COND_BRANCHES = re.compile(
    r"(?:true_computation=%([\w.\-]+),\s*false_computation=%([\w.\-]+)"
    r"|branch_computations=\{([^}]*)\})")
_CONST = re.compile(r"constant\((\d+)\)")
_DOT_DIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
# dot's lhs operand, in both HLO print dialects: untyped ``dot(%op, …)``
# and typed ``dot(f32[64,64]{1,0} %op, …)`` (the type carries the shape).
_DOT_LHS = re.compile(r" dot\((?:([a-z0-9]+)\[([0-9,]*)\]\S*\s+)?%([\w.\-]+)")
_OPERANDS = re.compile(r"\(([^)]*)\)")

_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")


def _parse_shape(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _parse_shape(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(text: str) -> int:
    shapes = _parse_shape(text)
    if not shapes:
        return 0
    n = 1
    for d in shapes[0][1]:
        n *= d
    return n


@dataclass
class Cost:
    flops: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.coll_bytes += o.coll_bytes
        for k, v in o.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.coll_bytes * f,
                    {k: v * f for k, v in self.coll_by_kind.items()})


@dataclass
class _Line:
    name: str
    result_type: str
    op_text: str


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[str]] = {}
        self.entry: Optional[str] = None
        self._split(hlo_text)
        self._memo: Dict[str, Cost] = {}

    # -- parsing -----------------------------------------------------------
    def _split(self, text: str) -> None:
        cur = None
        buf: List[str] = []
        for line in text.splitlines():
            m = _COMP_HDR.match(line)
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                if line.startswith("ENTRY"):
                    self.entry = cur
                buf = []
                self.computations[cur] = buf
            elif cur is not None:
                if line.startswith("}"):
                    cur = None
                else:
                    buf.append(line)

    # -- trip count --------------------------------------------------------
    def trip_count(self, cond_comp: str) -> int:
        """Largest integer constant in the loop condition (jax scans
        count 0..N-1 with an LT compare); 1 if none found."""
        best = 1
        seen = set()

        def walk(name):
            if name in seen or name not in self.computations:
                return
            seen.add(name)
            for line in self.computations[name]:
                for c in _CONST.findall(line):
                    best_local = int(c)
                    nonlocal best
                    best = max(best, best_local)
                for called in _CALLS.findall(line):
                    walk(called)

        walk(cond_comp)
        return best

    # -- per-line costs ------------------------------------------------------
    def _line_cost(self, line: str) -> Cost:
        c = Cost()
        m = _DEF.match(line)
        if not m:
            return c
        body = line[m.end(1):]
        # dot flops: 2 · prod(result dims) · K  (K = contracted size)
        if re.search(r"=\s*\S+\s+dot\(", line) or " dot(" in line:
            result_elems = _shape_elems(line.split("=", 1)[1])
            k = self._dot_contracted_size(line)
            c.flops += 2.0 * result_elems * k
        for kind in _COLLECTIVE_KINDS:
            if re.search(rf"\s{kind}(-start)?\(", line):
                b = _shape_bytes(line.split("=", 1)[1].split("(", 1)[0])
                c.coll_bytes += b
                c.coll_by_kind[kind] = c.coll_by_kind.get(kind, 0) + b
                break
        return c

    def _dot_contracted_size(self, line: str) -> int:
        m = _DOT_DIMS.search(line)
        if not m:
            return 1
        dims = [int(d) for d in m.group(1).split(",") if d]
        lhs = _DOT_LHS.search(line)
        shape = None
        if lhs is not None:
            if lhs.group(2) is not None:  # typed operand: shape inline
                shape = [int(d) for d in lhs.group(2).split(",") if d]
            else:                         # untyped: look up the def
                shape = self._operand_shapes.get(lhs.group(3))
        if shape is None:
            return 1
        k = 1
        for d in dims:
            if d < len(shape):
                k *= shape[d]
        return k

    # -- computation cost ----------------------------------------------------
    def computation_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Cost()  # cycle guard
        total = Cost()
        lines = self.computations.get(name, [])
        # operand shape env for dot contraction sizing
        self._operand_shapes = getattr(self, "_operand_shapes", {})
        for line in lines:
            m = _DEF.match(line)
            if m:
                shapes = _parse_shape(line.split("=", 1)[1].split("(")[0])
                if shapes:
                    self._operand_shapes[m.group(1)] = shapes[0][1]
        for line in lines:
            total += self._line_cost(line)
            w = _WHILE.search(line)
            if w and " while(" in line:
                cond, body = w.group(1), w.group(2)
                trips = self.trip_count(cond)
                total += self.computation_cost(body).scaled(trips)
                total += self.computation_cost(cond).scaled(trips)
                continue
            cb = _COND_BRANCHES.search(line)
            if cb and " conditional(" in line:
                branches = ([cb.group(1), cb.group(2)] if cb.group(1)
                            else [b.strip().lstrip("%") for b in
                                  cb.group(3).split(",")])
                costs = [self.computation_cost(b) for b in branches if b]
                if costs:
                    # one branch runs at runtime → max (hard routing)
                    best = max(costs, key=lambda x: x.flops)
                    total += best
                continue
            for called in _CALLS.findall(line):
                total += self.computation_cost(called)
        self._memo[name] = total
        return total

    def entry_cost(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.computation_cost(self.entry)


def loop_aware_costs(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).entry_cost()


# ---------------------------------------------------------------------------
# Pooled-decode expressed-cost report
# ---------------------------------------------------------------------------
#
# ``compiled.cost_analysis()`` on a pooled decode tick reports the cost
# the *program text* expresses, and the dense pooled path expresses a
# full (B, Hkv, L, D) KV read every tick regardless of how short the
# live prefixes are — the mask hides padding from the *result*, not from
# the roofline.  The Pallas kernel's per-row trip count
# (ceil(len_b / block_k), dead grid steps collapsed onto a repeat fetch
# by the index-map clamp) makes the expressed bytes/FLOPs track the
# LIVE prefix instead.  This section computes both analytically so the
# scaling claim is auditable without a TPU: sweep mean live length at a
# fixed buffer capacity and the dense column stays flat while the
# kernel column grows linearly.
#
# Counting conventions (deliberately conservative for the kernel):
#   * dense KV bytes    = B · Hkv · L_buf · (Dk + Dv) · dtype_bytes
#     (each batch row streams the whole buffer once; heads broadcast)
#   * kernel KV bytes   = Hq · Σ_b ceil(min(len_b, L_buf)/bk) · bk
#                         · (Dk + Dv) · dtype_bytes
#     (the grid iterates B·Hq rows and the kv index map is keyed on
#     b//G, so consecutive q-heads of one kv group REFETCH their
#     blocks — the honest per-grid-step count, not the ideal one)
#   * FLOPs             = 2 · (same block counts) · Hq per q-row
# Tiny q/output traffic (B·Hq·(Dk+Dv)) is omitted from both columns.

def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def ragged_lengths(batch: int, live_max: int) -> List[int]:
    """Deterministic mixed-length pool: evenly spaced 1..live_max."""
    live_max = max(1, live_max)
    return [max(1, round(live_max * (i + 1) / batch))
            for i in range(batch)]


def pooled_decode_attn_cost(lengths: List[int], buffer_len: int, *,
                            n_q_heads: int, n_kv_heads: int,
                            d_k: int, d_v: int, block_k: int = 128,
                            dtype_bytes: int = 4) -> Dict[str, float]:
    """Expressed HBM bytes and MXU FLOPs for ONE pooled attention
    consult (one layer, one decode step), dense vs kernel."""
    B = len(lengths)
    row_bytes = (d_k + d_v) * dtype_bytes
    dense_bytes = B * n_kv_heads * buffer_len * row_bytes
    dense_flops = 2.0 * B * n_q_heads * buffer_len * (d_k + d_v)
    kv_cols = sum(_ceil_div(min(n, buffer_len), block_k) * block_k
                  for n in lengths)
    kernel_bytes = n_q_heads * kv_cols * row_bytes
    kernel_flops = 2.0 * n_q_heads * kv_cols * (d_k + d_v)
    return {
        "dense_hbm_bytes": float(dense_bytes),
        "kernel_hbm_bytes": float(kernel_bytes),
        "dense_flops": dense_flops,
        "kernel_flops": kernel_flops,
        "bytes_ratio": kernel_bytes / max(dense_bytes, 1),
    }


def decode_linear_cost(n_params: int, params_bytes: int, *,
                       batch: int, n_steps: int = 1) -> Dict[str, float]:
    """Expressed non-attention cost of pooled decode: every step runs
    the full parameter set once per batch row (2·N FLOPs per token)
    and streams the params from HBM once per step (the batch shares
    one read — decode is famously parameter-bandwidth-bound)."""
    return {
        "flops": 2.0 * float(n_params) * batch * n_steps,
        "hbm_bytes": float(params_bytes) * n_steps,
    }


def pooled_decode_tick_cost(lengths: List[int],
                            layer_specs: List[Tuple],
                            *, n_steps: int = 1,
                            kernel_hits: Optional[List[bool]] = None,
                            block_k: int = 128) -> Dict:
    """Expressed attention cost of one pooled decode tick across all
    attention layers — the join the serving profiler uses.

    ``layer_specs`` holds one (buffer_len, n_q_heads, n_kv_heads, d_k,
    d_v, dtype_bytes) tuple per attention layer (the engine derives
    them from static cache shapes); ``kernel_hits[i]`` selects the
    kernel column (live-length block trips) for layers the decode
    kernel served and the dense column (full buffer sweep) for
    declined/dense layers — None means all-dense.  Returns totals plus
    the kernel-hit / kernel-decline split, each scaled by ``n_steps``.
    """
    if kernel_hits is None:
        kernel_hits = [False] * len(layer_specs)
    if len(kernel_hits) != len(layer_specs):
        raise ValueError(
            f"pooled_decode_tick_cost: {len(kernel_hits)} kernel_hits "
            f"for {len(layer_specs)} layer specs — the kernel trace and "
            f"the geometry specs must describe the same layers")
    out: Dict = {
        "flops": 0.0, "hbm_bytes": 0.0,
        "kernel_hit": {"layers": 0, "flops": 0.0, "hbm_bytes": 0.0},
        "kernel_decline": {"layers": 0, "flops": 0.0, "hbm_bytes": 0.0},
    }
    for (buf, hq, hkv, dk, dv, db), hit in zip(layer_specs, kernel_hits):
        c = pooled_decode_attn_cost(lengths, buf, n_q_heads=hq,
                                    n_kv_heads=hkv, d_k=dk, d_v=dv,
                                    block_k=block_k, dtype_bytes=db)
        fl = (c["kernel_flops"] if hit else c["dense_flops"]) * n_steps
        hb = (c["kernel_hbm_bytes"] if hit
              else c["dense_hbm_bytes"]) * n_steps
        out["flops"] += fl
        out["hbm_bytes"] += hb
        side = out["kernel_hit" if hit else "kernel_decline"]
        side["layers"] += n_steps  # layer-consults: layers × steps
        side["flops"] += fl
        side["hbm_bytes"] += hb
    return out


def pooled_decode_report(cfg, *, max_len: int, batch: int = 8,
                         block_k: int = 128, dtype_bytes: int = 4,
                         fracs=(0.125, 0.25, 0.5, 0.75, 1.0)) -> Dict:
    """Per-tick expressed-cost sweep for every decode geometry the slot
    pool routes for ``cfg`` (a ModelConfig): FullKV (buffer = max_len),
    RingKV (buffer = sink + local, when flux routing is on) and MLA
    absorbed decode (latent KV, Hkv = 1) when the config is MLA.

    Each row fixes the buffer capacity and sweeps the mean live prefix;
    dense bytes are constant down the sweep while kernel bytes scale
    with the live prefix — the acceptance check for the pooled kernel.
    """
    geoms = []
    if cfg.kv_lora_rank:
        d_k = cfg.kv_lora_rank + cfg.qk_rope_head_dim
        geoms.append(("mla-fullkv", max_len, cfg.num_heads, 1,
                      d_k, cfg.kv_lora_rank))
    else:
        geoms.append(("fullkv", max_len, cfg.num_heads, cfg.num_kv_heads,
                      cfg.head_dim, cfg.head_dim))
    flux = getattr(cfg, "flux", None)
    if flux is not None and getattr(flux, "enabled", False):
        ring = min(flux.sink + flux.local, max_len)
        if cfg.kv_lora_rank:
            d_k = cfg.kv_lora_rank + cfg.qk_rope_head_dim
            geoms.append(("mla-ringkv", ring, cfg.num_heads, 1,
                          d_k, cfg.kv_lora_rank))
        else:
            geoms.append(("ringkv", ring, cfg.num_heads,
                          cfg.num_kv_heads, cfg.head_dim, cfg.head_dim))
    report: Dict = {"batch": batch, "block_k": block_k,
                    "dtype_bytes": dtype_bytes, "geometries": {}}
    for name, buf, hq, hkv, dk, dv in geoms:
        rows = []
        for frac in fracs:
            lens = ragged_lengths(batch, int(round(frac * buf)))
            cost = pooled_decode_attn_cost(
                lens, buf, n_q_heads=hq, n_kv_heads=hkv, d_k=dk, d_v=dv,
                block_k=block_k, dtype_bytes=dtype_bytes)
            rows.append({"live_frac": frac, "mean_len":
                         sum(lens) / len(lens), **cost})
        report["geometries"][name] = {
            "buffer_len": buf, "n_q_heads": hq, "n_kv_heads": hkv,
            "d_k": dk, "d_v": dv, "rows": rows}
    return report


def format_pooled_report(report: Dict) -> str:
    out = []
    for name, g in report["geometries"].items():
        out.append(f"{name}: buffer={g['buffer_len']} Hq={g['n_q_heads']} "
                   f"Hkv={g['n_kv_heads']} Dk={g['d_k']} Dv={g['d_v']}")
        out.append(f"  {'live':>6} {'mean_len':>9} {'dense MB':>10} "
                   f"{'kernel MB':>10} {'ratio':>7}")
        for r in g["rows"]:
            out.append(
                f"  {r['live_frac']:>6.3f} {r['mean_len']:>9.1f} "
                f"{r['dense_hbm_bytes'] / 1e6:>10.3f} "
                f"{r['kernel_hbm_bytes'] / 1e6:>10.3f} "
                f"{r['bytes_ratio']:>7.3f}")
    return "\n".join(out)


def main() -> None:
    import argparse
    import json

    from repro.configs import ALL_ARCHS, get_config, smoke_variant

    ap = argparse.ArgumentParser(
        description="Analytic expressed-cost report for pooled decode")
    ap.add_argument("--arch", choices=ALL_ARCHS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--max-len", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--block-k", type=int, default=128)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also dump the report as JSON")
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    report = pooled_decode_report(cfg, max_len=args.max_len,
                                  batch=args.batch, block_k=args.block_k)
    print(format_pooled_report(report))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)


if __name__ == "__main__":
    main()
