"""Training launcher.

Runs the paper's router-training recipe end-to-end on real devices
(CPU-scale here; the same code path lowers for the production mesh —
dryrun.py proves it).  Example:

    PYTHONPATH=src python -m repro.launch.train \
        --arch phi3-mini-3.8b --smoke --steps 100 --batch 8 --seq 256
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from repro.configs import ALL_ARCHS, get_config, smoke_variant
from repro.data import mixture_iterator
from repro.models import model as MD
from repro.train import PretrainTrainer, RouterTrainer, checkpoint


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS, required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--pretrain-steps", type=int, default=0,
                    help="backbone pretraining steps before router "
                         "training (0 = random backbone)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="artifacts/train")
    ap.add_argument("--load", default=None,
                    help="checkpoint to initialize the backbone from")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    params = MD.init_params(jax.random.key(args.seed), cfg)
    if args.load:
        params = checkpoint.load(args.load, params)
    data = mixture_iterator(cfg.vocab_size, args.batch, args.seq,
                            seed=args.seed)

    history = {}
    if args.pretrain_steps:
        pt = PretrainTrainer(cfg, total_steps=args.pretrain_steps)
        st = pt.init(params)
        st, history["pretrain"] = pt.run(st, data, args.pretrain_steps)
        params = st["params"]

    if cfg.routable_layers() and cfg.flux.enabled:
        rt = RouterTrainer(cfg, total_steps=args.steps)
        state = rt.init(params, jax.random.key(args.seed + 1))
        state, history["router"] = rt.run(state, data, args.steps)
        params = rt.params(state)
    else:
        print(f"{cfg.name}: no routable attention layers — router "
              "training skipped (DESIGN.md §Arch-applicability)")

    os.makedirs(args.out, exist_ok=True)
    ck = os.path.join(args.out, f"{cfg.name}.msgpack")
    checkpoint.save(ck, params)
    with open(os.path.join(args.out, f"{cfg.name}_history.json"),
              "w") as f:
        json.dump(history, f, indent=2)
    print(f"saved {ck}")


if __name__ == "__main__":
    main()
