"""Serving launcher: batched requests through the flux engine.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch phi3-mini-3.8b --smoke --requests 4 --prompt-len 128
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ALL_ARCHS, get_config, smoke_variant
from repro.data.synthetic import SyntheticTasks
from repro.models import model as MD
from repro.serve import Request, ServeEngine, serve_batch
from repro.train import checkpoint


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--load", default=None)
    ap.add_argument("--dense", action="store_true",
                    help="disable sparse decode (paper's non-shaded rows)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    params = MD.init_params(jax.random.key(0), cfg)
    if args.load:
        params = checkpoint.load(args.load, params)

    gen = SyntheticTasks(cfg.vocab_size, seed=0)
    rng = np.random.default_rng(0)
    reqs = []
    for rid in range(args.requests):
        task = "needle" if rid % 2 == 0 else "markov"
        b = gen.batch(rng, task, 1, args.prompt_len)
        reqs.append(Request(rid=rid, tokens=b.tokens[0],
                            n_steps=args.gen_len))

    engine = ServeEngine(params, cfg,
                         max_len=args.prompt_len + args.gen_len + 8,
                         sparse_decode=not args.dense)
    t0 = time.time()
    results = serve_batch(engine, reqs)
    dt = time.time() - t0
    for rid in sorted(results):
        print(f"req {rid}: {results[rid][:8].tolist()} ...")
    print(f"{len(reqs)} requests, {args.gen_len} tokens each, "
          f"{dt:.2f}s wall")


if __name__ == "__main__":
    main()
