"""Serving launcher: batched or continuous requests through the engine.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch phi3-mini-3.8b --smoke --requests 4 --prompt-len 128

    # continuous batching: Poisson arrivals into the slot-pool scheduler
    PYTHONPATH=src python -m repro.launch.serve \
        --arch phi3-mini-3.8b --smoke --continuous --requests 8

    # with telemetry: Prometheus text + Perfetto trace of the drain
    PYTHONPATH=src python -m repro.launch.serve \
        --arch phi3-mini-3.8b --smoke --continuous --requests 8 \
        --metrics-out metrics.prom --trace-out trace.json
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import ALL_ARCHS, get_config, smoke_variant
from repro.data.synthetic import SyntheticTasks
from repro.kernels.decode_attention import make_kernel_decode_attn
from repro.models import model as MD
from repro.serve import (Request, ServeEngine, SLOConfig, STATUS_OK,
                         SHED_POLICIES, SHED_REJECT_NEWEST,
                         kv_cache, serve_batch_finished)
from repro.train import checkpoint


def _requests(cfg, args) -> list:
    gen = SyntheticTasks(cfg.vocab_size, seed=0)
    rng = np.random.default_rng(0)
    shared = (rng.integers(0, cfg.vocab_size,
                           size=args.shared_prefix).astype(np.int32)
              if args.shared_prefix else None)
    reqs = []
    for rid in range(args.requests):
        task = "needle" if rid % 2 == 0 else "markov"
        # continuous mode mixes prompt lengths — the traffic shape the
        # slot-pool scheduler exists for
        plen = (args.prompt_len if not args.continuous
                else args.prompt_len // (1 + rid % 3))
        b = gen.batch(rng, task, 1, max(plen, 16))
        toks = b.tokens[0]
        if shared is not None:
            # shared-system-prompt traffic: every request opens with the
            # same preamble — the prefix-cache hit path's home turf
            toks = np.concatenate([shared, toks]).astype(np.int32)
        reqs.append(Request(rid=rid, tokens=toks, n_steps=args.gen_len,
                            prefix_reuse=not args.no_prefix_reuse))
    return reqs


def _serve_continuous(engine: ServeEngine, reqs, args) -> None:
    sched = engine.scheduler(slots_per_bucket=args.slots, chunk=args.chunk)
    rng = np.random.default_rng(1)
    arrivals = np.cumsum(rng.exponential(args.mean_gap, len(reqs)))
    t0 = time.monotonic()
    pending = list(reqs)
    next_arrival = 0
    done = {}
    # loop on submitted-work-left, not result count: a shed request
    # retires at submit() time and is only announced by the next tick
    while pending or sched.waiting or sched.n_active():
        now = time.monotonic() - t0
        while pending and arrivals[next_arrival] <= now:
            engine.submit(pending.pop(0))
            next_arrival += 1
        if sched.waiting or sched.n_active():
            for f in engine.step():
                done[f.rid] = f
        elif pending:  # idle until the next Poisson arrival
            time.sleep(min(max(arrivals[next_arrival] - now, 0.0), 0.05))
    for f in sched.tick():  # announce any final submit-time sheds
        done[f.rid] = f
    wall = time.monotonic() - t0
    total = 0
    probes = engine.fidelity_probe_every > 0
    for rid in sorted(done):
        f, m = done[rid], done[rid].metrics
        total += m.n_generated
        # routing-fidelity columns only when probing was enabled: mean
        # attention-mass coverage + the worst SA-layer coverage for the
        # sampled admissions, '-' for the unsampled rest
        fid = ""
        if probes:
            fid = (f" cov={m.fidelity:.3f}" if m.fidelity is not None
                   else " cov=    -")
            fid += (f" sa_min={m.fidelity_sa_min:.3f}"
                    if m.fidelity_sa_min is not None else " sa_min=    -")
        print(f"req {rid} [{f.status:>9}]: {f.tokens[:8].tolist()} ... | "
              f"ttft={m.ttft * 1e3:6.1f}ms queue={m.queue_delay * 1e3:5.1f}ms "
              f"tps={m.decode_tps:6.1f} preempt={m.preemptions}{fid}")
    by_status = {}
    for f in done.values():
        by_status[f.status] = by_status.get(f.status, 0) + 1
    status_str = " ".join(f"{s}={n}" for s, n in sorted(by_status.items()))
    n_ok = by_status.get(STATUS_OK, 0)
    print(f"{len(done)} requests ({status_str}) | {total} tokens in "
          f"{wall:.2f}s ({total / wall:.0f} tok/s, "
          f"{n_ok}/{len(done)} ok) | geometries={sched.n_geometries()} "
          f"decode_executables={engine.decode_cache_size()} "
          f"ticks={sched.ticks} sa_level={engine.sa_level}")
    if engine.prefix_store is not None:
        s = engine.prefix_store.stats()
        hit = sum(done[r].metrics.prefix_hit_tokens for r in done)
        prompt = sum(done[r].metrics.prompt_len for r in done)
        print(f"prefix cache: {s.hits} hits / {s.misses} misses | "
              f"{hit}/{prompt} prompt tokens warm "
              f"({hit / max(prompt, 1):.0%}) | "
              f"device={s.device_bytes} B host={s.host_bytes} B "
              f"snapshots={s.snapshots}")


def _decode_kernel(cfg, args, max_len: int):
    """Build the decode-attention backend named by --decode-kernel.

    'on' is the loud variant: if every geometry this engine can route
    (FullKV buffers at ``max_len``, SA rings at sink+local) falls under
    the adapter's ``min_len`` decline threshold, the kernel would be
    accepted at construction yet decline every single call — the
    silent-forever failure ISSUE 8 closes.  Refuse to start instead."""
    if args.decode_kernel == "off":
        return None
    block_k = args.kernel_block_k
    min_len = 2 * block_k
    if args.decode_kernel == "on":
        candidates = {"full-cache": max_len}
        if cfg.flux.enabled:
            candidates["sa-ring"] = min(kv_cache.ring_size(cfg.flux),
                                        max_len)
        if all(length < min_len for length in candidates.values()):
            detail = " ".join(f"{name}={length}" for name, length
                              in sorted(candidates.items()))
            raise SystemExit(
                f"--decode-kernel on: no routed geometry can satisfy "
                f"the kernel's shape constraints — every cache extent "
                f"({detail}) is below min_len={min_len} "
                f"(= 2·block_k), so the adapter would decline every "
                f"call and serve dense forever.  Lower --kernel-block-k "
                f"or raise --prompt-len/--gen-len.")
    return make_kernel_decode_attn(block_k=block_k, min_len=min_len)


def _print_kernel_summary(engine: ServeEngine) -> None:
    if engine.decode_attn is None:
        return
    s = engine.decode_kernel_summary()
    declines = " ".join(f"{r}={n}" for r, n in
                        sorted(s["decline_layers"].items())) or "none"
    print(f"decode kernel: dispatches={s['dispatches']} "
          f"hit_layers={s['hit_layers']} declines: {declines}")


def _write_telemetry(engine: ServeEngine, args) -> None:
    """Export the run's telemetry to the paths the flags named (no-op
    when neither flag was passed)."""
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(engine.metrics_text())
        print(f"metrics → {args.metrics_out}")
    if args.trace_out:
        engine.export_trace(args.trace_out)
        print(f"trace   → {args.trace_out} "
              f"(open in https://ui.perfetto.dev)")
    if args.profile_every:
        rep = engine.profiler_report()
        print(f"profiler: {rep['sampled_ticks']} sampled ticks "
              f"(every {rep['every']})")
        for ph in rep["phases"]:
            print(f"  {ph['phase']:>14}: host={ph['host_s'] * 1e3:8.2f}ms "
                  f"device={ph['device_s'] * 1e3:8.2f}ms "
                  f"({ph['host_frac']:.0%} host) "
                  f"achieved={ph['achieved_gflops_per_s']:7.1f} GFLOP/s "
                  f"{ph['achieved_gbytes_per_s']:6.1f} GB/s "
                  f"n={ph['count']}")
    if args.ledger_out:
        rep = engine.attribution_report()
        led = rep["ledger"]
        with open(args.ledger_out, "w") as f:
            json.dump(rep, f, indent=2)
        recon, snap = led["reconciliation"], led["snapshot"]
        if snap is None:
            # batch-synchronous path: no scheduler ticked, so the ledger
            # never snapshotted — the report still carries kv_cache_stats
            print(f"ledger  → {args.ledger_out} (no tick snapshots; "
                  f"use --continuous for the per-tick ledger)")
        else:
            print(f"ledger  → {args.ledger_out} | "
                  f"device={snap['device_bytes']} B "
                  f"hwm={snap['device_high_watermark_bytes']} B "
                  f"frag={snap['fragmentation_bytes']} B | "
                  f"reconciliation payload_delta={recon['payload_delta']} "
                  f"overhead_delta={recon['overhead_delta']} "
                  f"(aux={led['aux_bytes']})")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--load", default=None)
    ap.add_argument("--dense", action="store_true",
                    help="disable sparse decode (paper's non-shaded rows)")
    ap.add_argument("--decode-kernel", choices=("off", "auto", "on"),
                    default="off",
                    help="Pallas flash-decode backend for the decode "
                         "scan: 'auto' installs it and lets the adapter "
                         "decline per-layer (dense fallback below "
                         "min_len = 2·block_k); 'on' additionally "
                         "refuses to start if NO routed geometry could "
                         "ever satisfy the kernel's shape constraints "
                         "(the silently-declining-forever trap)")
    ap.add_argument("--kernel-block-k", type=int, default=128,
                    help="KV block size of the decode kernel; the "
                         "adapter's min_len heuristic is 2·block_k")
    ap.add_argument("--continuous", action="store_true",
                    help="slot-pool continuous batching instead of "
                         "batch-synchronous bucketing")
    ap.add_argument("--slots", type=int, default=4,
                    help="slot-pool capacity per geometry bucket")
    ap.add_argument("--chunk", type=int, default=8,
                    help="decode steps per scheduler tick")
    ap.add_argument("--mean-gap", type=float, default=0.02,
                    help="mean Poisson interarrival gap (s)")
    ap.add_argument("--prefill-chunk", type=int, default=512,
                    help="max chunk of the chunked cache-resident "
                         "prefill (prefix snapshots land at multiples "
                         "of this; 0 = monolithic admission)")
    ap.add_argument("--prefix-cache-mb", type=float, default=0.0,
                    help="device byte budget (MB) for the shared-prefix "
                         "snapshot store; 0 disables prefix reuse")
    ap.add_argument("--prefix-cache-host-mb", type=float, default=0.0,
                    help="host offload tier budget (MB): evicted "
                         "snapshots demote to CPU instead of dropping")
    ap.add_argument("--no-prefix-reuse", action="store_true",
                    help="submit requests opted out of prefix reuse "
                         "(store stays configured but untouched)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend a shared system prompt of this many "
                         "tokens to every request")
    # SLO guardrails (serve/slo.py); all off by default
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bound on the waiting queue; overflow is shed "
                         "per --shed-policy (0 = unbounded)")
    ap.add_argument("--shed-policy", choices=SHED_POLICIES,
                    default=SHED_REJECT_NEWEST,
                    help="who a full queue rejects")
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="default per-request deadline in seconds; "
                         "expired work retires with status 'timeout' "
                         "(0 = no deadline)")
    ap.add_argument("--preemption-budget", type=int, default=-1,
                    help="max recompute-preemptions per request before "
                         "it becomes non-evictable (-1 = unbudgeted)")
    ap.add_argument("--aging-s", type=float, default=0.0,
                    help="waiting seconds per +1 admission priority "
                         "(anti-starvation; 0 = no aging)")
    ap.add_argument("--adaptive-sparsity", action="store_true",
                    help="bias Layer Router decisions toward SA under "
                         "queue pressure (load-adaptive sparsity dial)")
    # telemetry (DESIGN.md §Observability); either flag enables it
    ap.add_argument("--metrics-out", default=None,
                    help="write Prometheus text exposition of the run's "
                         "metrics here (enables engine telemetry)")
    ap.add_argument("--trace-out", default=None,
                    help="write the request-span Chrome-trace/Perfetto "
                         "JSON here (enables engine telemetry; open in "
                         "https://ui.perfetto.dev)")
    # cost attribution (DESIGN.md §Observability); all off by default
    ap.add_argument("--profile-every", type=int, default=0,
                    help="sample every Nth scheduler tick for the "
                         "host/device cost profiler (adds sync "
                         "boundaries ONLY on sampled ticks; 0 = off)")
    ap.add_argument("--fidelity-probe-every", type=int, default=0,
                    help="probe every Nth admission's attention-mass "
                         "coverage per routed layer (0 = off)")
    ap.add_argument("--ledger-out", default=None,
                    help="enable the device-memory ledger and write its "
                         "reconciled JSON report (with the profiler "
                         "table when --profile-every is set) here")
    ap.add_argument("--mesh-model", type=int, default=0,
                    help="tensor-parallel width: shard KV heads and "
                         "attention/MLP weights over an N-way 'model' "
                         "mesh axis (0 = single-device serving; on CPU "
                         "set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8 "
                         "before launch)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    params = MD.init_params(jax.random.key(0), cfg)
    if args.load:
        params = checkpoint.load(args.load, params)

    reqs = _requests(cfg, args)
    slo = SLOConfig(
        max_queue=args.max_queue or None,
        shed_policy=args.shed_policy,
        default_deadline_s=args.deadline_s or None,
        preemption_budget=(None if args.preemption_budget < 0
                           else args.preemption_budget),
        aging_s=args.aging_s or None,
        adaptive_sparsity=args.adaptive_sparsity)
    telemetry = bool(args.metrics_out or args.trace_out)
    max_len = args.prompt_len + args.shared_prefix + args.gen_len + 8
    decode_attn = _decode_kernel(cfg, args, max_len)
    mesh = None
    if args.mesh_model > 1:
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh(data=1, model=args.mesh_model)
        print(f"mesh: (1, {args.mesh_model}) over "
              f"{len(jax.devices())} devices")
    engine = ServeEngine(params, cfg, max_len=max_len,
                         sparse_decode=not args.dense,
                         decode_attn=decode_attn,
                         prefill_chunk=args.prefill_chunk or None,
                         prefix_cache_mb=args.prefix_cache_mb or None,
                         prefix_cache_host_mb=args.prefix_cache_host_mb,
                         slo=slo, telemetry=telemetry,
                         profile_every=args.profile_every,
                         fidelity_probe_every=args.fidelity_probe_every,
                         memory_ledger=bool(args.ledger_out),
                         mesh=mesh)
    if args.continuous:
        _serve_continuous(engine, reqs, args)
        _print_kernel_summary(engine)
        _write_telemetry(engine, args)
        return
    t0 = time.time()
    results = serve_batch_finished(engine, reqs)
    dt = time.time() - t0
    for rid in sorted(results):
        f = results[rid]
        print(f"req {rid} [{f.status:>7}]: {f.tokens[:8].tolist()} ...")
    n_ok = sum(f.status == STATUS_OK for f in results.values())
    print(f"{len(reqs)} requests ({n_ok} ok), {args.gen_len} tokens each, "
          f"{dt:.2f}s wall")
    _print_kernel_summary(engine)
    _write_telemetry(engine, args)


if __name__ == "__main__":
    main()
