"""Workload builders: (jit-able fn, abstract args, in_shardings) per
(architecture × input shape × mesh) — consumed by dryrun.py and the
real launchers.

  train_4k     → RouterTrainer.step_impl (the paper's training recipe:
                 frozen backbone, router + λ updates, soft routing).
  prefill_32k  → MD.prefill with live hard routing (lax.cond per layer).
  prefill_chunked_32k → MD.prefill_chunk: one streamed chunk of the
                 cache-resident prefill writing into decode-geometry
                 caches (seq_len = cache capacity, ``chunk`` = bucket).
  decode_*     → MD.decode_step under a representative static routing
                 pattern (Ω_MSR = 0.5 interleave over routed layers —
                 §3.3: the pattern is fixed after prefill).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.core import policies
from repro.launch import shardings as SH
from repro.models import model as MD
from repro.serve import kv_cache as KC
from repro.train.train_loop import RouterTrainer


@dataclass
class Workload:
    name: str
    fn: Callable                       # positional-args callable
    args: Tuple[Any, ...]              # ShapeDtypeStructs / abstract
    in_shardings: Tuple[Any, ...]
    rules: Dict                        # logical rules for `constrain`
    model_flops: Optional[float] = None


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda k: MD.init_params(k, cfg),
                          jax.random.key(0))


def _extra_inputs(cfg: ModelConfig, B: int):
    extra = {}
    if cfg.family == "vlm":
        extra["prefix_embeddings"] = jax.ShapeDtypeStruct(
            (B, cfg.num_prefix_tokens, cfg.d_model), cfg.dtype)
    if cfg.family == "audio":
        extra["encoder_frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_ctx, cfg.d_model), cfg.dtype)
    return extra


def representative_pattern(cfg: ModelConfig, msr: float = 0.5):
    """Static Ω=0.5 interleave routing over routed layers."""
    arr = policies.static_pattern(cfg, msr, "interleave")
    return tuple(
        ("fa" if arr[i] else "sa") if kind == "attn" else None
        for i, kind in enumerate(cfg.layer_kinds))


def model_flops_estimate(cfg: ModelConfig, shape: InputShape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); decode D=B·1."""
    n = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens  # forward only
    return 2.0 * n * shape.global_batch  # one token per sequence


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------

def build_train(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                seq_shard: bool = True) -> Workload:
    B, S = shape.global_batch, shape.seq_len
    trainer = RouterTrainer(cfg, total_steps=300)
    params = abstract_params(cfg)
    state = jax.eval_shape(lambda p: trainer.init(p), params)
    i32, f32 = jnp.int32, jnp.float32
    extra = _extra_inputs(cfg, B)

    def fn(state, tokens, labels, loss_mask, task_type, rng, *extra_args):
        kw = dict(zip(sorted(extra), extra_args))
        return trainer.step_impl(state, tokens, labels, loss_mask,
                                 task_type, rng, **kw)

    rngspec = jax.eval_shape(lambda: jax.random.key(0))
    args = (state,
            jax.ShapeDtypeStruct((B, S), i32),
            jax.ShapeDtypeStruct((B, S), i32),
            jax.ShapeDtypeStruct((B, S), f32),
            jax.ShapeDtypeStruct((B,), i32),
            rngspec) + tuple(extra[k] for k in sorted(extra))

    repl = SH.replicated(mesh)
    state_sh = {
        "trainable": SH.param_shardings(state["trainable"], mesh),
        "frozen": SH.param_shardings(state["frozen"], mesh),
        "lagrange": jax.tree.map(lambda _: repl, state["lagrange"]),
        "opt_router": jax.tree.map(lambda _: repl, state["opt_router"]),
        "opt_lagrange": jax.tree.map(lambda _: repl,
                                     state["opt_lagrange"]),
        "step": repl,
    }
    in_sh = (state_sh,
             SH.batch_sharding(mesh, (B, S)),
             SH.batch_sharding(mesh, (B, S)),
             SH.batch_sharding(mesh, (B, S)),
             SH.batch_sharding(mesh, (B,)),
             repl) + tuple(
        SH.batch_sharding(mesh, extra[k].shape) for k in sorted(extra))
    rules = SH.TRAIN_RULES if seq_shard else dict(SH.TRAIN_RULES,
                                                  seq=None)
    tag = "" if seq_shard else "[no-seq-shard]"
    return Workload(f"train{tag}", fn, args, in_sh, rules,
                    model_flops_estimate(cfg, shape))


def build_prefill(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                  routing_ctx: str = "hard") -> Workload:
    B, S = shape.global_batch, shape.seq_len
    params = abstract_params(cfg)
    extra = _extra_inputs(cfg, B)
    routable = bool(cfg.routable_layers()) and cfg.flux.enabled
    ctx = routing_ctx if routable else "fa_only"

    def fn(params, tokens, *extra_args):
        kw = dict(zip(sorted(extra), extra_args))
        return MD.prefill(params, cfg, tokens, routing_ctx=ctx,
                          want_cache=True, **kw)

    args = (params, jax.ShapeDtypeStruct((B, S), jnp.int32)) + tuple(
        extra[k] for k in sorted(extra))
    in_sh = (SH.param_shardings(params, mesh),
             SH.batch_sharding(mesh, (B, S))) + tuple(
        SH.batch_sharding(mesh, extra[k].shape) for k in sorted(extra))
    return Workload(f"prefill[{ctx}]", fn, args, in_sh, SH.PREFILL_RULES,
                    model_flops_estimate(cfg, shape))


def build_prefill_chunked(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                          msr: float = 0.5, chunk: int = 2048) -> Workload:
    """One streamed chunk of the chunked cache-resident prefill
    (DESIGN.md §Prefill pipeline): tokens (B, chunk) + decode-geometry
    caches sized to ``shape.seq_len`` + a traced start offset."""
    B, S = shape.global_batch, shape.seq_len
    chunk = min(chunk, S)
    params = abstract_params(cfg)
    routable = bool(cfg.routable_layers()) and cfg.flux.enabled
    pattern = (representative_pattern(cfg, msr) if routable else tuple(
        ("fa" if k == "attn" else None) for k in cfg.layer_kinds))
    caches = jax.eval_shape(
        lambda: KC.init_decode_caches(cfg, pattern, B, S))
    extra = {}
    if cfg.family == "audio":
        extra["enc_out"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_ctx, cfg.d_model), cfg.dtype)

    def fn(params, tokens, caches, start, *extra_args):
        kw = dict(zip(sorted(extra), extra_args))
        return MD.prefill_chunk(params, cfg, tokens, caches, start, **kw)

    args = (params, jax.ShapeDtypeStruct((B, chunk), jnp.int32), caches,
            jax.ShapeDtypeStruct((), jnp.int32)) + tuple(
        extra[k] for k in sorted(extra))
    in_sh = (SH.param_shardings(params, mesh),
             SH.batch_sharding(mesh, (B, chunk)),
             SH.cache_shardings(caches, mesh, B),
             SH.replicated(mesh)) + tuple(
        SH.batch_sharding(mesh, extra[k].shape) for k in sorted(extra))
    flops = model_flops_estimate(
        cfg, InputShape(shape.name, chunk, B, "prefill"))
    return Workload(f"prefill_chunked[msr={msr},c={chunk}]", fn, args,
                    in_sh, SH.PREFILL_RULES, flops)


def build_decode(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                 msr: float = 0.5, distributed_kv: bool = False,
                 decode_tp: bool = False) -> Workload:
    B, S = shape.global_batch, shape.seq_len
    params = abstract_params(cfg)
    routable = bool(cfg.routable_layers()) and cfg.flux.enabled
    pattern = (representative_pattern(cfg, msr) if routable else tuple(
        ("fa" if k == "attn" else None) for k in cfg.layer_kinds))
    caches = jax.eval_shape(
        lambda: KC.init_decode_caches(cfg, pattern, B, S))
    extra = {}
    if cfg.family == "audio":
        extra["enc_out"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_ctx, cfg.d_model), cfg.dtype)

    dd = di = None
    if distributed_kv:
        from repro.distributed.decode import (make_distributed_dot_decode,
                                              make_distributed_insert)
        seq_axes = tuple(a for a in ("pod", "data", "model")
                         if a in mesh.axis_names)
        dd = make_distributed_dot_decode(mesh, seq_axes)
        di = make_distributed_insert(mesh, seq_axes)

    def fn(params, token, caches, pos, *extra_args):
        kw = dict(zip(sorted(extra), extra_args))
        if dd is not None:
            with MD.use_decode_attn(dd), MD.use_cache_insert(di):
                return MD.decode_step(params, cfg, token, caches,
                                      pattern, pos, **kw)
        return MD.decode_step(params, cfg, token, caches, pattern, pos,
                              **kw)

    args = (params, jax.ShapeDtypeStruct((B, 1), jnp.int32), caches,
            jax.ShapeDtypeStruct((), jnp.int32)) + tuple(
        extra[k] for k in sorted(extra))
    psh = (SH.param_shardings_decode_tp(params, mesh) if decode_tp
           else SH.param_shardings(params, mesh))
    in_sh = (psh,
             SH.batch_sharding(mesh, (B, 1)),
             SH.cache_shardings(caches, mesh, B),
             SH.replicated(mesh)) + tuple(
        SH.batch_sharding(mesh, extra[k].shape) for k in sorted(extra))
    tag = ("+distkv" if distributed_kv else "") + \
        ("+tp" if decode_tp else "")
    return Workload(f"decode[msr={msr}]{tag}", fn, args, in_sh,
                    SH.DECODE_RULES, model_flops_estimate(cfg, shape))


def build_workload(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                   **kw) -> Workload:
    if shape.kind == "train":
        return build_train(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return build_prefill(cfg, shape, mesh, **kw)
    if shape.kind == "prefill_chunked":
        return build_prefill_chunked(cfg, shape, mesh, **kw)
    return build_decode(cfg, shape, mesh, **kw)
