"""command-r-plus-104b — dense GQA, no-bias.

[hf:CohereForAI/c4ai-command-r-v01] (scaled family config as assigned):
64L, d_model=12288, 96 heads (GQA kv=8), d_ff=33792, vocab=256000.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256000,
    rope_theta=75_000_000.0,
    tie_embeddings=True,  # command-r ties input/output embeddings
))
