"""Architecture configs (one module per assigned architecture)."""
from repro.configs.base import (  # noqa: F401
    SHAPES,
    FluxConfig,
    InputShape,
    ModelConfig,
    get_config,
    input_specs,
    list_configs,
    register,
    smoke_variant,
)

# Importing the arch modules registers their CONFIGs.
from repro.configs import (  # noqa: F401
    command_r_plus_104b,
    deepseek_v2_236b,
    gemma3_12b,
    granite_moe_3b_a800m,
    jamba_1_5_large_398b,
    mamba2_780m,
    phi3_mini_3_8b,
    phi_3_vision_4_2b,
    stablelm_12b,
    whisper_tiny,
)

ALL_ARCHS = (
    "command-r-plus-104b",
    "deepseek-v2-236b",
    "mamba2-780m",
    "whisper-tiny",
    "stablelm-12b",
    "phi-3-vision-4.2b",
    "granite-moe-3b-a800m",
    "phi3-mini-3.8b",
    "gemma3-12b",
    "jamba-1.5-large-398b",
)
