"""granite-moe-3b-a800m — MoE.

[hf:ibm-granite/granite-3.0 family]: 32L, d_model=1536, 24 heads
(GQA kv=8), 40 experts top-8, expert d_ff=512, vocab=49155.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    moe_layers="all",
    num_experts=40,
    top_k=8,
    moe_d_ff=512,
    tie_embeddings=True,
))
