"""jamba-1.5-large-398b — hybrid Mamba + attention (1:7) with MoE.

[arXiv:2403.19887]: 72L, d_model=8192, 64 heads (GQA kv=8), d_ff=24576,
MoE 16 experts top-2 (every second layer), vocab=65536.  Attention
appears once per 8-layer period (index 3, Jamba's published layout).
Flux routing applies to the 9 attention layers — at long context they
are exactly the expensive layers.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    layer_pattern=("mamba", "mamba", "mamba", "attn",
                   "mamba", "mamba", "mamba", "mamba"),
    moe_layers="even",
    num_experts=16,
    top_k=2,
    moe_d_ff=24576,
    ssm_state_dim=128,
    ssm_head_dim=64,
    ssm_expand=2,
))
