"""gemma3-12b — dense with 5:1 local:global attention interleave, 128k.

[hf:google/gemma-3 family]: 48L, d_model=3840, 16 heads (GQA kv=8),
d_ff=15360, vocab=262144.  Local layers use a 1024-token sliding
window; the Flux router controls the 1-in-6 global layers only
(local layers are already sparse) — DESIGN.md §Arch-applicability.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    layer_pattern=("local", "local", "local", "local", "local", "attn"),
    sliding_window=1024,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
))
