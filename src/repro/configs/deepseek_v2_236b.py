"""deepseek-v2-236b — MoE with Multi-head Latent Attention (MLA).

[arXiv:2405.04434]: 60L, d_model=5120, 128 heads, MLA kv_lora=512,
MoE: 2 shared + 160 routed experts, top-6, expert d_ff=1536,
vocab=102400.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,  # MLA decompresses to per-head K/V
    head_dim=128,
    d_ff=12288,        # (dense FFN would be 12288; all layers are MoE here)
    vocab_size=102400,
    moe_layers="all",
    num_experts=160,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1536,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    rope_theta=10000.0,
))
