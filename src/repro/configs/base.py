"""Configuration system for the Flux Attention framework.

Every assigned architecture gets one module in this package exposing
``CONFIG: ModelConfig``.  Configs are frozen dataclasses so they can be
used as static (hashable) arguments to ``jax.jit``.

The four assigned input shapes live in ``SHAPES``; ``input_specs`` builds
``jax.ShapeDtypeStruct`` stand-ins for every model input of a given
(config, shape) pair — no device allocation, suitable for ``.lower()``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Flux Attention (the paper's technique) configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FluxConfig:
    """Configuration of the paper's layer-level FA/SA routing.

    Defaults follow Table 3 of the paper, except ``block`` which is 128 on
    TPU (MXU tile) instead of the paper's 64 (CUDA); see DESIGN.md §2.
    """

    enabled: bool = True
    # Sparse-layer attention mode: "ssa" (StreamingLLM sink+local),
    # "xa" (XAttention antidiagonal block-sparse), "ta" (Triangle).
    sa_mode: str = "ssa"
    # StreamingLLM-style geometry (paper: sink 128 / local 2048).
    sink: int = 128
    local: int = 2048
    # Block-sparse geometry (paper: block 64 / chunk 16384 / stride 16 /
    # threshold 0.9).  Block is 128 on TPU.
    block: int = 128
    chunk: int = 16384
    stride: int = 16
    threshold: float = 0.9
    # Router (paper §3.1 / App. D.1): prefix-suffix pooling over the
    # boundary ``pool_size`` tokens, Context-Encoder MLP, Router Head.
    pool_size: int = 100
    router_hidden: int = 128
    # Gumbel-Softmax temperature annealing (paper §3.1).
    tau_start: float = 5.0
    tau_end: float = 0.1
    # Target sparse budgets t (paper §4.1: holistic 1.0, retrieval 0.45).
    target_retrieval: float = 0.45
    target_holistic: float = 1.0
    # Number of task categories carrying independent (λ1, λ2) multipliers.
    num_task_types: int = 2

    def replace(self, **kw) -> "FluxConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

# Block kinds appearing in ``layer_pattern``:
#   "attn"   — global self attention (flux-routable)
#   "local"  — sliding-window self attention (already sparse; not routed)
#   "mamba"  — Mamba2 SSD block (attention-free; not routed)
ATTN_KINDS = ("attn", "local")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | audio | vlm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # Layer pattern: repeated (cyclically) to cover ``num_layers``.
    layer_pattern: Tuple[str, ...] = ("attn",)
    # Which layers get a MoE FFN instead of a dense FFN.  "all", "even",
    # "none".  (Jamba applies MoE every second layer.)
    moe_layers: str = "none"

    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    # Expert capacity factor; >= num_experts ⇒ dropless (C clamps to T).
    moe_capacity_factor: float = 1.25

    # --- MLA (DeepSeek-V2) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- SSM (Mamba2 SSD) ---
    ssm_state_dim: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # --- Sliding window (gemma local layers) ---
    sliding_window: int = 1024

    # --- Encoder-decoder (whisper backbone) ---
    num_encoder_layers: int = 0
    encoder_ctx: int = 0  # number of (precomputed) audio frame embeddings

    # --- VLM (phi-3-vision) ---
    num_prefix_tokens: int = 0  # precomputed image patch embeddings

    # --- Common ---
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # Expressed-FLOP reduction for causal FA in the pure-XLA path
    # (§Perf): recursive sequence split depth (0 = off).
    causal_split_depth: int = 0
    tie_embeddings: bool = False
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.bfloat16

    flux: FluxConfig = field(default_factory=FluxConfig)

    # ------------------------------------------------------------------
    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Block kind for every layer (pattern repeated)."""
        p = self.layer_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim if self.ssm_state_dim else 0

    def moe_layer_mask(self) -> Tuple[bool, ...]:
        if self.moe_layers == "all":
            return tuple(True for _ in range(self.num_layers))
        if self.moe_layers == "even":
            return tuple(i % 2 == 0 for i in range(self.num_layers))
        return tuple(False for _ in range(self.num_layers))

    def routable_layers(self) -> Tuple[int, ...]:
        """Indices of layers the Flux router controls (global attention)."""
        return tuple(i for i, k in enumerate(self.layer_kinds) if k == "attn")

    # --- parameter counting (for roofline MODEL_FLOPS = 6·N·D) ---
    def param_count(self, active_only: bool = False) -> int:
        d, L = self.d_model, self.num_layers
        n = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * d
        for i, kind in enumerate(self.layer_kinds):
            if kind in ("attn", "local"):
                if self.use_mla:
                    qr = self.q_lora_rank or d
                    qk_hd = self.qk_nope_head_dim + self.qk_rope_head_dim
                    n += d * qr + qr * self.num_heads * qk_hd  # q
                    n += d * (self.kv_lora_rank + self.qk_rope_head_dim)
                    n += self.kv_lora_rank * self.num_heads * (
                        self.qk_nope_head_dim + self.v_head_dim)
                    n += self.num_heads * self.v_head_dim * d  # o
                else:
                    n += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            elif kind == "mamba":
                inner = self.ssm_inner
                nh = self.ssm_num_heads
                # in_proj produces [z, x, B, C, dt]
                n += d * (2 * inner + 2 * self.ssm_state_dim + nh)
                n += inner * d  # out_proj
                n += self.ssm_conv_width * (inner + 2 * self.ssm_state_dim)
            # FFN
            if self.moe_layer_mask()[i]:
                per_expert = 3 * d * self.moe_d_ff
                total_experts = self.num_experts + self.num_shared_experts
                active = self.top_k + self.num_shared_experts
                n += d * self.num_experts  # gate
                n += per_expert * (active if active_only else total_experts)
            else:
                n += 3 * d * self.d_ff  # SwiGLU: gate, up, down
            n += 2 * d  # norms
        # encoder (whisper): self-attn + ffn; decoder additionally carries
        # cross-attn (counted above only for self; add cross here)
        for _ in range(self.num_encoder_layers):
            n += 4 * d * self.q_dim + 3 * d * self.d_ff + 2 * d
        if self.num_encoder_layers:
            # decoder cross-attention per decoder layer
            n += self.num_layers * (4 * d * self.q_dim + d)
        return n


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | prefill_chunked | decode


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    # one streamed chunk of the chunked cache-resident prefill; seq_len
    # is the decode-cache capacity the chunk writes into
    "prefill_chunked_32k": InputShape("prefill_chunked_32k", 32768, 32,
                                      "prefill_chunked"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every input of (cfg, shape).

    ``train``   → tokens + labels + task_type (for the router's Lagrangian).
    ``prefill`` → tokens (+ modality prefix embeddings).
    ``decode``  → one new token per sequence + cache position.
    (Decode KV-cache specs are built by ``repro.serve.kv_cache.cache_specs``
    because their shapes depend on the routing pattern.)
    """
    B, S = shape.global_batch, shape.seq_len
    f32, i32 = jnp.float32, jnp.int32
    specs: Dict[str, Any] = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        specs["task_type"] = jax.ShapeDtypeStruct((B,), i32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    elif shape.kind == "prefill_chunked":
        specs["tokens"] = jax.ShapeDtypeStruct((B, min(2048, S)), i32)
        specs["start"] = jax.ShapeDtypeStruct((), i32)
    else:  # decode
        specs["token"] = jax.ShapeDtypeStruct((B, 1), i32)
        specs["cache_len"] = jax.ShapeDtypeStruct((), i32)
    if cfg.family == "vlm" and shape.kind != "decode":
        specs["prefix_embeddings"] = jax.ShapeDtypeStruct(
            (B, cfg.num_prefix_tokens, cfg.d_model), cfg.dtype)
    if cfg.family == "audio":
        specs["encoder_frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_ctx, cfg.d_model), cfg.dtype)
    return specs


# ---------------------------------------------------------------------------
# Registry + smoke variants
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # Import arch modules lazily so ``register`` runs.
    from repro.configs import ALL_ARCHS  # noqa: F401

    if name not in _REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> Tuple[str, ...]:
    from repro.configs import ALL_ARCHS  # noqa: F401

    return tuple(sorted(_REGISTRY))


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: 2 layers, d_model<=512, <=4 experts.

    Used by per-arch smoke tests to run a real forward/train step on CPU.
    """
    num_layers = min(cfg.num_layers, 2 * len(cfg.layer_pattern))
    # Keep the pattern but at most one period (so every kind is exercised)
    # while staying tiny: cap at len(pattern) or 2, whichever is bigger.
    num_layers = min(num_layers, max(2, len(cfg.layer_pattern)))
    d_model = min(cfg.d_model, 256)
    head_dim = 32
    num_heads = 4
    num_kv_heads = min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4
    kw: Dict[str, Any] = dict(
        num_layers=num_layers,
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv_heads,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512) or 0,
        vocab_size=min(cfg.vocab_size, 512),
        dtype=jnp.float32,
        param_dtype=jnp.float32,
        flux=cfg.flux.replace(
            sink=8, local=32, block=16, chunk=64, pool_size=8,
            router_hidden=16, stride=4),
        sliding_window=16,
    )
    if cfg.num_experts:
        kw.update(num_experts=min(cfg.num_experts, 4),
                  top_k=min(cfg.top_k, 2),
                  num_shared_experts=min(cfg.num_shared_experts, 1),
                  moe_d_ff=min(cfg.moe_d_ff, 128),
                  # dropless in smoke tests: decode/prefill consistency
                  # is exact (capacity drops are a large-scale trade-off)
                  moe_capacity_factor=float(min(cfg.num_experts, 4)))
    if cfg.use_mla:
        kw.update(q_lora_rank=64, kv_lora_rank=32,
                  qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32)
    if cfg.ssm_state_dim:
        kw.update(ssm_state_dim=16, ssm_head_dim=32, ssm_chunk=16)
    if cfg.num_encoder_layers:
        kw.update(num_encoder_layers=2, encoder_ctx=16)
    if cfg.num_prefix_tokens:
        kw.update(num_prefix_tokens=8)
    return cfg.replace(**kw)
