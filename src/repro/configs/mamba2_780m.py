"""mamba2-780m — attention-free SSM with SSD (state-space duality).

[arXiv:2405.21060]: 48L, d_model=1536, ssm_state=128, vocab=50280.
Flux routing is inapplicable (no attention) — see DESIGN.md
§Arch-applicability; the model runs with flux disabled.
"""
from repro.configs.base import FluxConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,  # mamba2 blocks have no separate FFN
    vocab_size=50280,
    layer_pattern=("mamba",),
    ssm_state_dim=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    tie_embeddings=True,
    flux=FluxConfig(enabled=False),
))
