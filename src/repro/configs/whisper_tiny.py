"""whisper-tiny — encoder-decoder audio backbone.

[arXiv:2212.04356]: 4L (enc + dec), d_model=384, 6 heads, d_ff=1536,
vocab=51865.  The mel-spectrogram + conv frontend is a STUB:
``input_specs`` provides precomputed frame embeddings (B, 1500, 384).
Flux routing applies to decoder self-attention only.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,            # decoder layers
    num_encoder_layers=4,
    encoder_ctx=1500,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    tie_embeddings=True,
))
