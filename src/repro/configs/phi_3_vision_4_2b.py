"""phi-3-vision-4.2b — phi3-mini backbone + CLIP vision frontend (stub).

[hf:microsoft/Phi-3-vision-128k-instruct]: 32L, d_model=3072, 32 heads
(kv=32), d_ff=8192, vocab=32064.  The ViT/projector is a STUB —
``input_specs`` provides projected patch embeddings (B, 576, 3072)
which the decoder consumes as a prefix.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    num_prefix_tokens=576,
))
