from repro.models import model  # noqa: F401
from repro.models.model import (  # noqa: F401
    ForwardOut,
    decode_core,
    decode_many,
    decode_step,
    forward_train,
    init_params,
    prefill,
)
