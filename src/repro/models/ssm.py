"""Mamba2 block via SSD (state-space duality), TPU-native chunked form.

The SSD algorithm [arXiv:2405.21060] decomposes the selective-scan into
(a) intra-chunk *matmul* blocks (MXU-friendly quadratic attention-like
contractions over chunks of length Q) and (b) a cheap inter-chunk
recurrence over per-chunk states — this is exactly the TPU adaptation
the paper's GPU scan kernels need (DESIGN.md §2): the quadratic piece
feeds the systolic array, the recurrence is a ``lax.scan`` over
S/Q steps.

All decay factors are exp of non-positive numbers (A < 0, dt > 0), so
the chunked form is numerically safe in bf16; accumulations are f32.

Decode is the O(1) recurrent step: h ← exp(dt·A)·h + dt·(B ⊗ x);
y = C·h + D·x, plus a (width-1)-deep causal-conv tail buffer.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rms_norm, rms_norm_init


def mamba_init(key, cfg: ModelConfig) -> Dict[str, jax.Array]:
    ks = jax.random.split(key, 4)
    d, dt = cfg.d_model, cfg.param_dtype
    inner, N, nh = cfg.ssm_inner, cfg.ssm_state_dim, cfg.ssm_num_heads
    conv_ch = inner + 2 * N
    return {
        # in_proj → [z(inner), xBC(inner+2N), dt(nh)]
        "in_proj": dense_init(ks[0], d, 2 * inner + 2 * N + nh, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_ch),
                                     jnp.float32) * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm": rms_norm_init(inner, dt),
        "out_proj": dense_init(ks[3], inner, d, dt),
    }


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B,S,C) with taps (W,C)."""
    W = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1]] * w[i] for i in range(W))
    return out + b


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int,
                h0: jax.Array = None) -> Tuple[jax.Array, jax.Array]:
    """SSD over a sequence.

    x (B,S,H,P); dt (B,S,H) (post-softplus); A (H,) (<0); Bm/Cm (B,S,N)
    (shared across heads, ngroups=1).  Returns (y (B,S,H,P),
    final_state (B,H,P,N)).
    """
    B, S, H, Pd = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q
    xc = x.reshape(B, nc, Q, H, Pd)
    dtc = dt.reshape(B, nc, Q, H).astype(jnp.float32)
    Bc = Bm.reshape(B, nc, Q, N)
    Cc = Cm.reshape(B, nc, Q, N)

    dA = dtc * A  # (B,nc,Q,H), ≤ 0
    cum = jnp.cumsum(dA, axis=2)  # inclusive within chunk

    # --- intra-chunk (quadratic, MXU) ---
    CB = jnp.einsum("bcin,bcjn->bcij", Cc, Bc,
                    preferred_element_type=jnp.float32)  # (B,nc,Q,Q)
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])
    ii, jj = jnp.arange(Q)[:, None], jnp.arange(Q)[None, :]
    causal = (ii >= jj)[None, None, :, :, None]
    scores = jnp.where(causal, CB[..., None] * decay, 0.0)  # (B,nc,Q,Q,H)
    xbar = xc * dtc[..., None].astype(xc.dtype)  # dt enters as input scale
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores.astype(xc.dtype), xbar,
                         preferred_element_type=jnp.float32)

    # --- per-chunk states ---
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,Q,H)
    S_c = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Bc,
                     (decay_end * dtc).astype(xc.dtype), xc,
                     preferred_element_type=jnp.float32)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,nc,H)

    # --- inter-chunk recurrence ---
    if h0 is None:
        h0 = jnp.zeros((B, H, Pd, N), jnp.float32)

    def step(h, inp):
        dec, s_c = inp  # dec (B,H), s_c (B,H,P,N)
        h_prev = h
        h = h * dec[:, :, None, None] + s_c
        return h, h_prev

    dec_seq = jnp.moveaxis(chunk_decay, 1, 0)  # (nc,B,H)
    s_seq = jnp.moveaxis(S_c, 1, 0)            # (nc,B,H,P,N)
    h_final, h_prevs = lax.scan(step, h0, (dec_seq, s_seq))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)  # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp", Cc,
                         h_prevs.astype(xc.dtype),
                         jnp.exp(cum).astype(xc.dtype),
                         preferred_element_type=jnp.float32)
    y = (y_intra + y_inter).reshape(B, Sp, H, Pd)[:, :S]
    return y.astype(x.dtype), h_final


def _split_proj(params, cfg: ModelConfig, x: jax.Array):
    inner, N, nh = cfg.ssm_inner, cfg.ssm_state_dim, cfg.ssm_num_heads
    zxbcdt = x @ params["in_proj"]
    z = zxbcdt[..., :inner]
    xBC = zxbcdt[..., inner:2 * inner + 2 * N]
    dt_raw = zxbcdt[..., 2 * inner + 2 * N:]
    return z, xBC, dt_raw


def mamba_apply(params, cfg: ModelConfig, x: jax.Array,
                state=None) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full-sequence (train/prefill) Mamba2 block.

    x (B,S,d) → (y (B,S,d), (ssd_state (B,H,P,N) f32, conv_tail
    (B,W-1,C))).  ``state`` optionally carries (h0, conv_tail) for
    chunked prefill.
    """
    B, S, _ = x.shape
    W1 = cfg.ssm_conv_width - 1
    inner, N, nh = cfg.ssm_inner, cfg.ssm_state_dim, cfg.ssm_num_heads
    z, xBC, dt_raw = _split_proj(params, cfg, x)
    if state is not None and state[1] is not None:
        xBC_in = jnp.concatenate([state[1], xBC], axis=1)
        conv_full = _causal_conv(xBC_in, params["conv_w"], params["conv_b"])
        conv = conv_full[:, state[1].shape[1]:]
    else:
        # left-pad with the conv's implicit zero history so the emitted
        # tail is always (B, W-1, C), even for sequences shorter than
        # the conv window (single-bucket chunks in the chunked prefill)
        xBC_in = jnp.pad(xBC, ((0, 0), (W1, 0), (0, 0)))
        conv = _causal_conv(xBC, params["conv_w"], params["conv_b"])
    conv = jax.nn.silu(conv)
    xs = conv[..., :inner].reshape(B, S, nh, cfg.ssm_head_dim)
    Bm = conv[..., inner:inner + N]
    Cm = conv[..., inner + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    h0 = state[0] if state is not None else None
    y, h_final = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk, h0)
    y = y + (params["D"].astype(y.dtype)[:, None] * xs)
    y = y.reshape(B, S, inner)
    y = rms_norm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    conv_tail = xBC_in[:, -W1:] if W1 else xBC[:, :0]
    return y @ params["out_proj"], (h_final, conv_tail)


def mamba_decode_step(params, cfg: ModelConfig, x: jax.Array,
                      ssd_state: jax.Array, conv_tail: jax.Array):
    """Single-token recurrent step.

    x (B,1,d); ssd_state (B,H,P,N) f32; conv_tail (B,W-1,C).
    Returns (y (B,1,d), new_ssd_state, new_conv_tail).
    """
    B = x.shape[0]
    inner, N, nh = cfg.ssm_inner, cfg.ssm_state_dim, cfg.ssm_num_heads
    z, xBC, dt_raw = _split_proj(params, cfg, x)
    window = jnp.concatenate([conv_tail, xBC], axis=1)  # (B,W,C)
    conv = jnp.einsum("bwc,wc->bc", window, params["conv_w"]
                      ) + params["conv_b"]
    conv = jax.nn.silu(conv)
    xs = conv[:, :inner].reshape(B, nh, cfg.ssm_head_dim)
    Bm = conv[:, inner:inner + N]
    Cm = conv[:, inner + N:]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A)  # (B,H)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xs.astype(jnp.float32),
                     Bm.astype(jnp.float32))
    h = ssd_state * dA[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", h, Cm.astype(jnp.float32))
    y = y + params["D"][:, None] * xs.astype(jnp.float32)
    y = y.reshape(B, 1, inner).astype(x.dtype)
    y = rms_norm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    new_tail = jnp.concatenate([conv_tail[:, 1:], xBC], axis=1)
    return y @ params["out_proj"], h, new_tail
