"""Model assembly: init + three drivers (train / prefill / decode).

Layer layout.  ``cfg.layer_pattern`` defines a period of block kinds
(e.g. Jamba's 8-layer Mamba/attention interleave); the trunk params are
stored *stacked by period position* — ``trunk[pos]`` is a pytree whose
leaves carry a leading ``n_periods`` axis.  Train and prefill drivers
``lax.scan`` over periods (compile time stays O(period), not O(L));
decode unrolls a python loop over layers because the per-layer cache
*shapes* depend on the cache geometry chosen at repack time — the
paper's sparse-decode memory saving is structural (kv_cache.py).
Generation itself is a second ``lax.scan`` over decode steps
(``decode_many``): sampling stays on device and the sampled-token →
next-step dependency never round-trips to the host.

Flux routing contexts:
  ("soft", tau, rng)   — Gumbel-Softmax blend of FA and SA (Eq. 5), train.
  ("hard",)            — router argmax per layer (batch consensus) at
                         prefill, executed via lax.cond (§3.3).
  ("fixed", pattern)   — externally forced decisions (static baselines,
                         dry-run patterns, ablations).
  ("fa_only",)         — backbone as-is (flux disabled).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.tree_util import register_dataclass

from repro.configs.base import ModelConfig
from repro.core import modes as M
from repro.core import router as R
from repro.distributed import constrain
from repro.models import attention as A
from repro.models import moe as MOE
from repro.models import ssm as S
from repro.kernels.decode_attention import PooledValid
from repro.models.layers import (dense_init, embed_init, ffn_apply, ffn_init,
                                 rms_norm, rms_norm_init)
from repro.serve import kv_cache as KC


# ---------------------------------------------------------------------------
# Structure helpers
# ---------------------------------------------------------------------------

def period_len(cfg: ModelConfig) -> int:
    return len(cfg.layer_pattern)


def n_periods(cfg: ModelConfig) -> int:
    assert cfg.num_layers % period_len(cfg) == 0, (
        f"{cfg.name}: num_layers {cfg.num_layers} not divisible by "
        f"pattern length {period_len(cfg)}")
    return cfg.num_layers // period_len(cfg)


def has_ffn(cfg: ModelConfig, layer_idx: int) -> bool:
    return cfg.d_ff > 0 or cfg.moe_layer_mask()[layer_idx]


def is_routed(cfg: ModelConfig, layer_idx: int) -> bool:
    return (cfg.flux.enabled
            and cfg.layer_kinds[layer_idx] == "attn")


def router_in_dim(cfg: ModelConfig) -> int:
    if cfg.use_mla:
        return cfg.num_heads * (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    return cfg.q_dim


def sa_mode(cfg: ModelConfig) -> M.AttnMode:
    return M.sa_mode_for(cfg.flux)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig, layer_idx: int) -> Dict[str, Any]:
    kind = cfg.layer_kinds[layer_idx]
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {"norm1": rms_norm_init(cfg.d_model, cfg.param_dtype)}
    if kind in ("attn", "local"):
        p["attn"] = (A.mla_init(ks[0], cfg) if cfg.use_mla
                     else A.gqa_init(ks[0], cfg))
        if is_routed(cfg, layer_idx):
            p["router"] = R.router_init(ks[1], router_in_dim(cfg), cfg.flux)
        if cfg.num_encoder_layers:  # whisper decoder: cross attention
            p["norm_x"] = rms_norm_init(cfg.d_model, cfg.param_dtype)
            d = cfg.d_model
            kx = jax.random.split(ks[2], 4)
            p["xattn"] = {
                "wq": dense_init(kx[0], d, cfg.q_dim, cfg.param_dtype),
                "wk": dense_init(kx[1], d, cfg.q_dim, cfg.param_dtype),
                "wv": dense_init(kx[2], d, cfg.q_dim, cfg.param_dtype),
                "wo": dense_init(kx[3], cfg.q_dim, d, cfg.param_dtype),
            }
    elif kind == "mamba":
        p["mamba"] = S.mamba_init(ks[0], cfg)
    if has_ffn(cfg, layer_idx):
        p["norm2"] = rms_norm_init(cfg.d_model, cfg.param_dtype)
        if cfg.moe_layer_mask()[layer_idx]:
            p["moe"] = MOE.moe_init(ks[3], cfg)
        else:
            p["ffn"] = ffn_init(ks[3], cfg.d_model, cfg.d_ff, cfg.param_dtype)
    return p


def _enc_block_init(key, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    kx = jax.random.split(ks[0], 4)
    return {
        "norm1": rms_norm_init(d, cfg.param_dtype),
        "attn": {
            "wq": dense_init(kx[0], d, cfg.q_dim, cfg.param_dtype),
            "wk": dense_init(kx[1], d, cfg.q_dim, cfg.param_dtype),
            "wv": dense_init(kx[2], d, cfg.q_dim, cfg.param_dtype),
            "wo": dense_init(kx[3], cfg.q_dim, d, cfg.param_dtype),
        },
        "norm2": rms_norm_init(d, cfg.param_dtype),
        "ffn": ffn_init(ks[1], d, cfg.d_ff, cfg.param_dtype),
    }


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    P, NP = period_len(cfg), n_periods(cfg)
    keys = jax.random.split(key, cfg.num_layers + cfg.num_encoder_layers + 2)
    # trunk: for each period position, stack params over periods.
    trunk = []
    for pos in range(P):
        per_period = [_block_init(keys[per * P + pos], cfg, per * P + pos)
                      for per in range(NP)]
        trunk.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_period))
    params: Dict[str, Any] = {
        "embed": embed_init(keys[-1], cfg.vocab_size, cfg.d_model,
                            cfg.param_dtype),
        "final_norm": rms_norm_init(cfg.d_model, cfg.param_dtype),
        "trunk": tuple(trunk),
    }
    if not cfg.tie_embeddings:
        params["out_w"] = dense_init(keys[-2], cfg.d_model, cfg.vocab_size,
                                     cfg.param_dtype)
    if cfg.num_encoder_layers:
        enc = [_enc_block_init(keys[cfg.num_layers + i], cfg)
               for i in range(cfg.num_encoder_layers)]
        params["encoder"] = {
            "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
            "final_norm": rms_norm_init(cfg.d_model, cfg.param_dtype),
        }
    return params


def router_param_filter(params: Dict[str, Any]) -> Dict[str, Any]:
    """Pytree mask: True on Layer-Router leaves (the only trainable part
    when reproducing the paper's parameter-efficient training)."""
    def mark(path, leaf):
        return any(getattr(p, "key", None) == "router" for p in path)
    return jax.tree_util.tree_map_with_path(mark, params)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _cross_attention(p, cfg: ModelConfig, h: jax.Array,
                     enc_out: jax.Array) -> jax.Array:
    """Whisper decoder cross-attention (bidirectional over encoder)."""
    B, S, _ = h.shape
    E = enc_out.shape[1]
    q = (h @ p["wq"]).reshape(B, S, cfg.num_heads, cfg.head_dim
                              ).transpose(0, 2, 1, 3)
    k = (enc_out @ p["wk"]).reshape(B, E, cfg.num_heads, cfg.head_dim
                                    ).transpose(0, 2, 1, 3)
    v = (enc_out @ p["wv"]).reshape(B, E, cfg.num_heads, cfg.head_dim
                                    ).transpose(0, 2, 1, 3)
    o = M.attention(q, k, v, M.BIDIRECTIONAL)
    return o.transpose(0, 2, 1, 3).reshape(B, S, -1) @ p["wo"]


def _route_and_attend(bp, cfg: ModelConfig, q, k, v, x_q, ctx,
                      q_offset=0):
    """Run FA / SA / blend per the routing context.

    Returns (attn_out, r) where r is:
      soft  → r_soft (B,) FA probability
      hard  → (decision scalar {0,1}, p_fa mean)
      fixed → (decision, decision)
      fa_only → None
    """
    flux = cfg.flux
    sa = sa_mode(cfg)
    kind = ctx[0]
    if kind == "fa_only":
        return M.attention(q, k, v, M.FULL, q_offset=q_offset,
                           split_depth=cfg.causal_split_depth), None
    if kind == "head_split":
        # DuoAttention/PruLong-style static head-level baseline.
        return M.head_split_attention(q, k, v, ctx[1], sa,
                                      q_offset=q_offset), None
    if kind == "soft":
        _, tau, rng = ctx
        r = R.soft_route(bp["router"], x_q, flux, tau, rng)  # (B,)
        o_fa = M.attention(q, k, v, M.FULL, q_offset=q_offset,
                           split_depth=cfg.causal_split_depth)
        o_sa = M.attention(q, k, v, sa, q_offset=q_offset)
        rb = r[:, None, None, None].astype(o_fa.dtype)
        return rb * o_fa + (1 - rb) * o_sa, r
    if kind in ("hard", "hard_prefix"):
        # "hard_prefix" pools the prefix only — the chunk-invariant
        # serving variant (router.pool_prefix); "hard" is the paper's
        # prefix+suffix pooling over the full sequence.
        pooling = "prefix" if kind == "hard_prefix" else "prefix_suffix"
        r_hard, p_fa = R.hard_route(bp["router"], x_q, flux, pooling)
        # batch-consensus scalar decision (per-request when B=1; the
        # engine buckets requests by routing pattern otherwise).  The
        # threshold is a *traced* scalar when the load-adaptive
        # sparsity dial is engaged (router.sa_biased_threshold) — 0.5
        # is the paper's argmax, and tracing keeps every dial setting
        # on one compiled prefill executable.
        thr = ctx[1] if len(ctx) > 1 else 0.5
        decision = (jnp.mean(p_fa) > thr).astype(jnp.int32)
    else:  # fixed
        decision = ctx[1]
        p_fa = None
    out = lax.cond(
        decision > 0,
        lambda qkv: M.attention(*qkv, M.FULL, q_offset=q_offset,
                                split_depth=cfg.causal_split_depth),
        lambda qkv: M.attention(*qkv, sa, q_offset=q_offset),
        (q, k, v))
    p_mean = jnp.mean(p_fa) if p_fa is not None else decision.astype(
        jnp.float32) if hasattr(decision, "astype") else jnp.float32(decision)
    return out, (decision, p_mean)


def block_apply(bp, cfg: ModelConfig, layer_idx: int, h: jax.Array,
                positions: jax.Array, ctx, enc_out=None,
                mamba_state=None, want_cache: bool = False):
    """One transformer block (train/prefill path over a full sequence).

    Returns (h, r, cache, aux): r is the routing record for routed
    layers else None; cache is the layer's prefill KV when
    ``want_cache`` (k/v | (ckv, kr) | (ssd_state, conv_tail)).
    """
    kind = cfg.layer_kinds[layer_idx]
    cache = None
    aux: Dict[str, Any] = {}
    r = None
    x = rms_norm(bp["norm1"], h, cfg.norm_eps)
    if kind == "mamba":
        y, (ssd_state, conv_tail) = S.mamba_apply(bp["mamba"], cfg, x,
                                                  mamba_state)
        if want_cache:
            cache = (ssd_state, conv_tail)
        h = h + y
    elif kind in ("attn", "local"):
        if cfg.use_mla:
            ckv, kr = A.mla_latent(bp["attn"], cfg, x, positions)
            q, x_q = A.mla_q(bp["attn"], cfg, x, positions)
            k, v = A.mla_expand_kv(bp["attn"], cfg, ckv, kr)
            if want_cache:
                cache = (ckv, kr)
        else:
            q, k, v, x_q = A.gqa_qkv(bp["attn"], cfg, x, positions)
            if want_cache:
                cache = (k, v)
        if kind == "local":
            o = M.attention(q, k, v, M.window_mode(cfg.sliding_window))
        elif is_routed(cfg, layer_idx) and ctx[0] != "fa_only":
            o, r = _route_and_attend(bp, cfg, q, k, v, x_q, ctx)
        else:
            o = M.attention(q, k, v, M.FULL,
                            split_depth=cfg.causal_split_depth)
        h = h + (A.mla_out(bp["attn"], cfg, o) if cfg.use_mla
                 else A.gqa_out(bp["attn"], cfg, o))
        if "xattn" in bp and enc_out is not None:
            hx = rms_norm(bp["norm_x"], h, cfg.norm_eps)
            h = h + _cross_attention(bp["xattn"], cfg, hx, enc_out)
    if has_ffn(cfg, layer_idx):
        x2 = rms_norm(bp["norm2"], h, cfg.norm_eps)
        if "moe" in bp:
            y2, moe_aux = MOE.moe_apply(bp["moe"], cfg, x2)
            aux["moe_balance"] = moe_aux["balance_loss"]
            aux["moe_drop"] = moe_aux["drop_fraction"]
        else:
            y2 = ffn_apply(bp["ffn"], x2)
        h = h + y2
    return h, r, cache, aux


# ---------------------------------------------------------------------------
# Encoder (whisper backbone)
# ---------------------------------------------------------------------------

def encode(params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """Bidirectional encoder over precomputed frame embeddings."""
    enc = params["encoder"]

    def body(h, bp):
        x = rms_norm(bp["norm1"], h, cfg.norm_eps)
        B, E, _ = x.shape
        q = (x @ bp["attn"]["wq"]).reshape(B, E, cfg.num_heads, cfg.head_dim
                                           ).transpose(0, 2, 1, 3)
        k = (x @ bp["attn"]["wk"]).reshape(B, E, cfg.num_heads, cfg.head_dim
                                           ).transpose(0, 2, 1, 3)
        v = (x @ bp["attn"]["wv"]).reshape(B, E, cfg.num_heads, cfg.head_dim
                                           ).transpose(0, 2, 1, 3)
        o = M.attention(q, k, v, M.BIDIRECTIONAL)
        h = h + o.transpose(0, 2, 1, 3).reshape(B, E, -1) @ bp["attn"]["wo"]
        x2 = rms_norm(bp["norm2"], h, cfg.norm_eps)
        return h + ffn_apply(bp["ffn"], x2), None

    h, _ = lax.scan(body, frames.astype(cfg.dtype), enc["layers"])
    return rms_norm(enc["final_norm"], h, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ModelConfig, tokens: jax.Array,
                 prefix_embeddings=None) -> jax.Array:
    h = params["embed"].astype(cfg.dtype)[tokens]
    if prefix_embeddings is not None:
        h = jnp.concatenate([prefix_embeddings.astype(cfg.dtype), h], axis=1)
    return constrain(h, "batch", "seq", "embed")


def unembed_matrix(params, cfg: ModelConfig) -> jax.Array:
    return params["embed"].T if cfg.tie_embeddings else params["out_w"]


def logits_from_hidden(params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    h = rms_norm(params["final_norm"], h, cfg.norm_eps)
    logits = h @ unembed_matrix(params, cfg).astype(h.dtype)
    return constrain(logits, "batch", None, "vocab")


# ---------------------------------------------------------------------------
# Train / prefill drivers (scan over periods)
# ---------------------------------------------------------------------------

@register_dataclass
@dataclass
class ForwardOut:
    logits: jax.Array           # (B, S, V) train / (B, V) prefill-last
    r_soft: Optional[jax.Array]   # (B, n_routed) FA probs (train)
    routing: Optional[jax.Array]  # (n_routed,) hard decisions (prefill)
    p_fa: Optional[jax.Array]     # (n_routed,) mean FA prob (prefill)
    aux: Dict[str, jax.Array]
    caches: Any = None


def _trunk_scan(params, cfg: ModelConfig, h: jax.Array, positions,
                ctx_builder, enc_out=None, want_cache: bool = False,
                remat: bool = False):
    """Scan over periods; python loop over the period positions inside."""
    P = period_len(cfg)

    def body(carry, xs):
        h = carry
        per_idx, trunk_slice = xs
        rs, caches, auxes = [], [], {}
        for pos in range(P):
            layer_idx_static = pos  # static within period
            ctx = ctx_builder(per_idx, pos)
            h, r, cache, aux = block_apply(
                trunk_slice[pos], cfg, layer_idx_static, h, positions, ctx,
                enc_out=enc_out, want_cache=want_cache)
            if r is not None:
                rs.append(r)
            if cache is not None:
                caches.append(cache)
            for k_, v_ in aux.items():
                auxes[k_] = auxes.get(k_, 0.0) + v_
        # keep the carried residual stream sharded (the scan's saved
        # activations dominate training memory at 100B scale; "seq" maps
        # to the model axis under the launch layer's Megatron-SP-style
        # rules)
        h = constrain(h, "batch", "seq", "embed")
        return h, (tuple(rs), tuple(caches), auxes)

    if remat:
        body = jax.checkpoint(body)
    xs = (jnp.arange(n_periods(cfg)), params["trunk"])
    h, (rs, caches, auxes) = lax.scan(body, h, xs)
    return h, rs, caches, auxes


def forward_train(params, cfg: ModelConfig, tokens: jax.Array, *,
                  rng=None, tau=1.0, prefix_embeddings=None,
                  encoder_frames=None, remat: bool = True,
                  flux_soft: bool = True,
                  output_hidden: bool = False) -> ForwardOut:
    """Training forward with Gumbel-Softmax soft routing (Eq. 4–5).

    ``output_hidden=True`` returns the final-normed hidden states in
    ``.logits`` instead of vocabulary logits — callers then use
    ``chunked_cross_entropy`` so the (B,S,V) tensor is never
    materialized (essential at 256k vocab)."""
    B, Stok = tokens.shape
    enc_out = (encode(params, cfg, encoder_frames)
               if cfg.num_encoder_layers else None)
    h = embed_tokens(params, cfg, tokens, prefix_embeddings)
    positions = jnp.arange(h.shape[1])
    P = period_len(cfg)

    use_soft = flux_soft and cfg.flux.enabled and rng is not None

    def ctx_builder(per_idx, pos):
        if not use_soft or cfg.layer_kinds[pos] != "attn":
            return ("fa_only",)
        layer_rng = jax.random.fold_in(jax.random.fold_in(rng, pos), per_idx)
        return ("soft", tau, layer_rng)

    h, rs, _, auxes = _trunk_scan(params, cfg, h, positions, ctx_builder,
                                  enc_out=enc_out, remat=remat)
    prefix = h.shape[1] - Stok
    h = h[:, prefix:] if prefix else h
    if output_hidden:
        logits = rms_norm(params["final_norm"], h, cfg.norm_eps)
    else:
        logits = logits_from_hidden(params, cfg, h)
    r_soft = None
    if use_soft and rs:
        # rs: tuple over routed positions of (n_periods, B) → (B, n_routed)
        stacked = jnp.stack(rs, axis=1)  # (n_periods, n_pos_routed, B)
        r_soft = jnp.transpose(stacked, (2, 0, 1)).reshape(B, -1)
    return ForwardOut(logits=logits, r_soft=r_soft, routing=None, p_fa=None,
                      aux=auxes)


def prefill(params, cfg: ModelConfig, tokens: jax.Array, *,
            routing_ctx: str = "hard", fixed_pattern=None,
            head_split_n: int = 0, prefix_embeddings=None,
            encoder_frames=None, want_cache: bool = True,
            fa_threshold=None) -> ForwardOut:
    """Serving prefill: hard routing (or a fixed pattern), full KV out.

    ``fixed_pattern``: (num_layers,) int array (1=FA, 0=SA) or None.
    ``routing_ctx="head_split"`` runs the DuoAttention-style baseline
    with ``head_split_n`` retrieval KV heads per layer.
    ``routing_ctx="hard_prefix"`` is hard routing with prefix-only
    pooling — decisions depend only on the first ``pool_size`` tokens,
    so a chunked prefill routing on its first chunk reproduces them
    exactly (DESIGN.md §Prefill pipeline).
    ``fa_threshold``: traced scalar FA-decision threshold for the hard
    routing contexts (None = the paper's 0.5 argmax).  The serving
    engine passes ``router.sa_biased_threshold`` rungs here for the
    load-adaptive sparsity dial; tracing it keeps one executable
    across every dial setting.
    """
    B, Stok = tokens.shape
    enc_out = (encode(params, cfg, encoder_frames)
               if cfg.num_encoder_layers else None)
    h = embed_tokens(params, cfg, tokens, prefix_embeddings)
    positions = jnp.arange(h.shape[1])
    P = period_len(cfg)
    if fixed_pattern is not None:
        fixed_pattern = jnp.asarray(fixed_pattern).reshape(n_periods(cfg), P)
    thr = (None if fa_threshold is None
           else jnp.asarray(fa_threshold, jnp.float32))

    def ctx_builder(per_idx, pos):
        if cfg.layer_kinds[pos] != "attn":
            return ("fa_only",)
        if routing_ctx == "head_split":
            return ("head_split", head_split_n)
        if not cfg.flux.enabled or routing_ctx == "fa_only":
            return ("fa_only",)
        if routing_ctx == "fixed":
            return ("fixed", fixed_pattern[per_idx, pos])
        key = "hard_prefix" if routing_ctx == "hard_prefix" else "hard"
        return (key,) if thr is None else (key, thr)

    h, rs, caches, auxes = _trunk_scan(params, cfg, h, positions,
                                       ctx_builder, enc_out=enc_out,
                                       want_cache=want_cache)
    logits = logits_from_hidden(params, cfg, h[:, -1])
    routing = p_fa = None
    if rs:
        # rs: tuple over routed positions of tuples (decision (n_periods,),
        # p_mean (n_periods,)) — stack to (n_routed,) in layer order.
        dec = jnp.stack([r[0] for r in rs], axis=1)   # (n_periods, n_pos)
        pfa = jnp.stack([r[1] for r in rs], axis=1)
        routing = dec.reshape(-1)
        p_fa = pfa.reshape(-1)
    return ForwardOut(logits=logits, r_soft=None, routing=routing,
                      p_fa=p_fa, aux=auxes, caches=caches if want_cache
                      else None)


# ---------------------------------------------------------------------------
# Decode driver (python loop over layers; polymorphic on cache geometry)
#
# The static axis of the compiled decode step is the per-layer *cache
# geometry* — FullKV vs RingKV vs LatentKV vs RingLatentKV, which
# genuinely changes compiled buffer shapes and flows in implicitly as
# the caches pytree structure.  The fa/sa/duo routing pattern itself is
# NOT static: any residual behavioral distinction between patterns that
# share a geometry (today: how many KV heads of a full-cache layer run
# full vs streaming attention) is traced data (``fa_heads``), so one
# executable serves every routing pattern with the same geometry
# (DESIGN.md §Serving) instead of one per pattern (2^routable worst
# case for the old routing-tuple static argument).
# ---------------------------------------------------------------------------

def layer_params(params, cfg: ModelConfig, layer_idx: int):
    P = period_len(cfg)
    per, pos = divmod(layer_idx, P)
    return jax.tree.map(lambda a: a[per], params["trunk"][pos])


def _rope_positions(pos: jax.Array) -> jax.Array:
    """Decode-step RoPE positions: (1,) shared when ``pos`` is scalar,
    (B, 1) per-slot when ``pos`` is (B,) — ``apply_rope`` broadcasts
    either against the length-1 sequence axis."""
    return pos[None] if jnp.ndim(pos) == 0 else pos[:, None]


def _causal_valid(L: int, pos: jax.Array, batch: int) -> jax.Array:
    """(B, L) per-row causal mask over a full cache (slots ≤ pos)."""
    idx = jnp.arange(L)
    if jnp.ndim(pos) == 0:
        return jnp.broadcast_to(idx <= pos, (batch, L))
    return idx[None, :] <= pos[:, None]


def _decode_attn_full(bp, cfg, x, pos, cache: KC.FullKV):
    positions = _rope_positions(pos)
    if cfg.use_mla:
        ckv, kr = A.mla_latent(bp["attn"], cfg, x, positions)
        cache = KC.latent_insert(cache, ckv, kr, pos)
        valid = _causal_valid(cache.ckv.shape[1], pos, x.shape[0])
        y = _mla_decode(bp, cfg, x, positions, cache.ckv, cache.kr,
                        valid, lengths=cache.length, ring_positions=None)
        return y, cache
    q, k, v, _ = A.gqa_qkv(bp["attn"], cfg, x, positions)
    cache = _full_kv_insert(cache, k, v, pos)
    if jnp.ndim(pos) == 0:
        # uniform positions → 1-D mask, eligible for the kernel /
        # distributed decode overrides
        valid = jnp.arange(cache.k.shape[2]) <= pos  # (Smax,)
    else:
        # per-slot positions → pooled validity: the dense (B, 1, Smax)
        # mask plus the (B,) live-prefix lengths a pooled kernel trips
        # on (FullKV slot i holds position i, so positions=None)
        valid = PooledValid(
            mask=_causal_valid(cache.k.shape[2], pos,
                               x.shape[0])[:, None],
            lengths=cache.length)
    o = _dot_decode(q, cache.k, cache.v, valid)
    return A.gqa_out(bp["attn"], cfg, o), cache


def _mla_decode(bp, cfg, x, positions, ckv, kr, valid, *, lengths,
                ring_positions):
    """Absorbed MLA decode step with the override fast path.

    When a pooled-capable decode override is installed, the absorbed
    attention is re-expressed as GQA-shaped (q, k, v) with Hkv = 1
    (``mla_absorbed_qkv``) and offered with per-slot validity; the
    kernel returns the latent context and ``mla_absorbed_finish``
    applies the absorbed output projection.  Decline → dense absorbed
    softmax, bit-for-bit the old path."""
    if _DECODE_ATTN_OVERRIDE and getattr(
            _DECODE_ATTN_OVERRIDE[-1], "supports_pooled", False):
        q_eff, k_eff, v_eff, scale = A.mla_absorbed_qkv(
            bp["attn"], cfg, x, positions, ckv, kr)
        pv = PooledValid(mask=valid, lengths=lengths,
                         positions=ring_positions)
        ctx = _consult_decode_attn(q_eff, k_eff, v_eff, pv, scale=scale)
        if ctx is not None:
            return A.mla_absorbed_finish(bp["attn"], cfg, ctx)
    return A.mla_absorbed_decode(bp["attn"], cfg, x, positions, ckv, kr,
                                 valid)


def _decode_attn_ring(bp, cfg, x, pos, cache, sink: int, local: int):
    positions = _rope_positions(pos)
    pos_col = pos if jnp.ndim(pos) == 0 else pos[:, None]
    if cfg.use_mla:
        ckv, kr = A.mla_latent(bp["attn"], cfg, x, positions)
        cache = KC.ring_latent_insert(cache, ckv, kr, pos, sink, local)
        valid = (cache.positions >= 0) & (cache.positions <= pos_col)
        ring = cache.positions.shape[1]
        y = _mla_decode(bp, cfg, x, positions, cache.ckv, cache.kr,
                        valid,
                        lengths=jnp.minimum(cache.length, ring),
                        ring_positions=jnp.where(valid, cache.positions,
                                                 -1))
        return y, cache
    q, k, v, _ = A.gqa_qkv(bp["attn"], cfg, x, positions)
    cache = KC.ring_insert(cache, k, v, pos, sink, local)
    if jnp.ndim(pos) == 0:
        # uniform positions keep every row of cache.positions identical
        # (repack + scalar-pos inserts), so a 1-D mask is exact — and
        # keeps ring layers eligible for the kernel/distributed
        # decode-attention overrides
        valid = (cache.positions[0] >= 0) & (cache.positions[0] <= pos)
    else:
        # per-slot (B, ring) bookkeeping → pooled validity.  Ring
        # occupancy is a contiguous slot prefix of min(length, ring)
        # entries, so a pooled kernel trips on that count; positions
        # outside the dense mask are re-marked -1 so the kernel's
        # occupancy test is structurally identical to the dense mask.
        mask2 = (cache.positions >= 0) & (cache.positions <= pos_col)
        valid = PooledValid(
            mask=mask2[:, None],
            lengths=jnp.minimum(cache.length,
                                cache.positions.shape[1]),
            positions=jnp.where(mask2, cache.positions, -1))
    o = _dot_decode(q, cache.k, cache.v, valid)
    return A.gqa_out(bp["attn"], cfg, o), cache


import contextlib as _contextlib

# Pluggable decode-attention implementation: the launch layer installs
# the shard_map LSE-combine path for sequence-sharded caches
# (repro.distributed.decode); default is the local dot product.
_DECODE_ATTN_OVERRIDE = []
_CACHE_INSERT_OVERRIDE = []


@_contextlib.contextmanager
def use_decode_attn(fn):
    _DECODE_ATTN_OVERRIDE.append(fn)
    try:
        yield
    finally:
        _DECODE_ATTN_OVERRIDE.pop()


@_contextlib.contextmanager
def use_cache_insert(fn):
    """Install a sharded FullKV insert (repro.distributed.decode)."""
    _CACHE_INSERT_OVERRIDE.append(fn)
    try:
        yield
    finally:
        _CACHE_INSERT_OVERRIDE.pop()


def _full_kv_insert(cache: KC.FullKV, k_new, v_new, pos) -> KC.FullKV:
    # the distributed sharded insert handles uniform (scalar) positions
    # only; per-slot inserts stay on the local scatter path
    if _CACHE_INSERT_OVERRIDE and jnp.ndim(pos) == 0:
        out = _CACHE_INSERT_OVERRIDE[-1](cache.k, cache.v, k_new, v_new,
                                         pos)
        if out is not None:
            return KC.FullKV(k=out[0], v=out[1],
                             length=jnp.broadcast_to(
                                 pos + 1, cache.length.shape).astype(
                                     cache.length.dtype))
    return KC.full_insert(cache, k_new, v_new, pos)


def _consult_decode_attn(q, k, v, valid, scale=None):
    """Offer a decode to the installed override; None = run dense.

    Capability negotiation keeps legacy overrides (the distributed
    LSE-combine adapter, test fakes) callable with their historical
    4-positional signature: :class:`PooledValid` is only handed to fns
    advertising ``supports_pooled``, and a non-default ``scale`` only
    to fns advertising ``supports_scale``."""
    if not _DECODE_ATTN_OVERRIDE:
        return None
    fn = _DECODE_ATTN_OVERRIDE[-1]
    if isinstance(valid, PooledValid) and not getattr(
            fn, "supports_pooled", False):
        return None
    if scale is not None:
        if not getattr(fn, "supports_scale", False):
            return None
        return fn(q, k, v, valid, scale=scale)
    return fn(q, k, v, valid)


def _dot_decode(q, k, v, valid):
    """q (B,H,1,D), k/v (B,Hkv,L,D) → (B,H,1,D).

    valid is (L,) shared, (Hkv,L) per-kv-head (head-split baselines),
    (B,Hkv_or_1,L) per-row, or a :class:`PooledValid` carrying per-slot
    lengths/positions next to its dense (B,1,L) mask (continuous-
    batching slot pools, where every row is a different request at its
    own position — the batched pooled kernel's home turf)."""
    if isinstance(valid, PooledValid):
        out = _consult_decode_attn(q, k, v, valid)
        if out is not None:
            return out
        valid = valid.mask  # decline → dense per-row path
    elif _DECODE_ATTN_OVERRIDE and valid.ndim == 1:
        out = _consult_decode_attn(q, k, v, valid)
        if out is not None:  # override may decline (e.g. small ring)
            return out
    B, Hq, _, D = q.shape
    Hkv = k.shape[1]
    q5 = q.reshape(B, Hkv, Hq // Hkv, 1, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", q5, k,
                   preferred_element_type=jnp.float32) * D ** -0.5
    if valid.ndim == 1:
        vmask = valid[None, None, None, None, :]
    elif valid.ndim == 2:  # per-kv-head mask (head-split baselines)
        vmask = valid[None, :, None, None, :]
    else:  # (B, Hkv or 1, L) per-row mask
        vmask = valid[:, :, None, None, :]
    s = jnp.where(vmask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Hq, 1, D).astype(q.dtype)


def _decode_attn_headsplit(bp, cfg, x, pos, cache: KC.FullKV, n_fa_kv):
    """DuoAttention-style decode: the cache stays *full-shape* (ragged
    per-head histories are unrepresentable — the paper's §2.3 point);
    streaming heads merely mask, saving FLOPs but no HBM traffic.

    ``n_fa_kv`` may be a traced int32 scalar: the full/streaming head
    split only shapes a mask, so patterns differing in it share one
    executable (n_fa_kv == num_kv_heads reduces to full attention).
    """
    positions = _rope_positions(pos)
    q, k, v, _ = A.gqa_qkv(bp["attn"], cfg, x, positions)
    cache = _full_kv_insert(cache, k, v, pos)
    L = cache.k.shape[2]
    idx = jnp.arange(L)
    head_is_full = jnp.arange(cfg.num_kv_heads) < n_fa_kv
    if jnp.ndim(pos) == 0:
        full_valid = idx <= pos
        stream_valid = full_valid & ((idx < cfg.flux.sink)
                                     | (pos - idx < cfg.flux.local))
        per_head = jnp.where(head_is_full[:, None],
                             full_valid[None, :], stream_valid[None, :])
    else:  # per-slot positions → (B, Hkv, L)
        full_valid = idx[None, :] <= pos[:, None]
        stream_valid = full_valid & (
            (idx[None, :] < cfg.flux.sink)
            | (pos[:, None] - idx[None, :] < cfg.flux.local))
        per_head = jnp.where(head_is_full[None, :, None],
                             full_valid[:, None, :],
                             stream_valid[:, None, :])
    o = _dot_decode(q, cache.k, cache.v, per_head)
    return A.gqa_out(bp["attn"], cfg, o), cache


def decode_core(params, cfg: ModelConfig, token: jax.Array, caches: List,
                pos: jax.Array, enc_out=None, fa_heads=None,
                duo_layers: Optional[Tuple[int, ...]] = None):
    """One autoregressive step, dispatched on cache geometry.

    token (B,1) int32; ``pos`` is () int32 — all rows at the same
    position (single-request serving) — or (B,) int32 per-slot
    positions (continuous-batching slot pools: every row is an
    independent request, with per-row RoPE angles, causal masks and
    ring arithmetic).  Per-layer behavior derives from the cache
    *type* (ring ⇒ sink+local streaming attention, full/latent ⇒ full
    attention), so the compiled executable is keyed by geometry alone.
    ``duo_layers`` (static tuple of layer indices) marks full-cache GQA
    layers running a DuoAttention-style head split; for those,
    ``fa_heads`` (num_layers,) int32 — *traced* — gives the number of
    KV heads on full attention, so duo patterns differing only in the
    split share one executable.  Layers outside ``duo_layers`` keep the
    plain full-attention path (1-D validity mask, eligible for the
    kernel / distributed decode overrides).
    Returns (logits (B,V), new_caches).
    """
    h = embed_tokens(params, cfg, token)
    new_caches = []
    flux = cfg.flux
    for i, kind in enumerate(cfg.layer_kinds):
        bp = layer_params(params, cfg, i)
        cache = caches[i]
        x = rms_norm(bp["norm1"], h, cfg.norm_eps)
        if kind == "mamba":
            y, hstate, tail = S.mamba_decode_step(bp["mamba"], cfg, x,
                                                  cache.h, cache.conv_tail)
            cache = KC.MambaCache(h=hstate, conv_tail=tail)
            h = h + y
        else:
            if isinstance(cache, (KC.RingKV, KC.RingLatentKV)):
                sink = 0 if kind == "local" else flux.sink
                ring = (cache.ckv.shape[1]
                        if isinstance(cache, KC.RingLatentKV)
                        else cache.k.shape[2])
                y, cache = _decode_attn_ring(bp, cfg, x, pos, cache,
                                             sink, ring - sink)
            elif (duo_layers is not None and i in duo_layers
                  and fa_heads is not None and not cfg.use_mla):
                y, cache = _decode_attn_headsplit(bp, cfg, x, pos, cache,
                                                  fa_heads[i])
            else:
                y, cache = _decode_attn_full(bp, cfg, x, pos, cache)
            h = h + y
            if "xattn" in bp and enc_out is not None:
                hx = rms_norm(bp["norm_x"], h, cfg.norm_eps)
                h = h + _cross_attention(bp["xattn"], cfg, hx, enc_out)
        if has_ffn(cfg, i):
            x2 = rms_norm(bp["norm2"], h, cfg.norm_eps)
            if "moe" in bp:
                y2, _ = MOE.moe_apply(bp["moe"], cfg, x2)
            else:
                y2 = ffn_apply(bp["ffn"], x2)
            h = h + y2
        new_caches.append(cache)
    logits = logits_from_hidden(params, cfg, h[:, -1])
    return logits, new_caches


# ---------------------------------------------------------------------------
# Chunked cache-resident prefill (DESIGN.md §Prefill pipeline)
#
# Streams one prompt chunk through the trunk writing *directly into
# decode-geometry caches*: ``full_insert_chunk`` at FA layers,
# ``ring_insert_chunk`` at SA layers — peak live KV at SA layers is
# bounded by the ring, not the prompt, and the monolithic
# prefill→repack pass disappears from the hot path.  Like
# ``decode_core``, per-layer behavior derives from the cache *type*
# (ring ⇒ sink+local streaming, full/latent ⇒ full causal), so the
# compiled executable is keyed by (cache geometry, chunk bucket) and
# ``start`` stays traced — every chunk offset shares one executable.
# ---------------------------------------------------------------------------

def _chunk_attn_ring(bp, cfg: ModelConfig, x, positions, start, cache,
                     sink: int, local: int):
    """Chunk attention at a ring-cache layer: queries see the pre-insert
    ring (explicit per-slot positions) plus the chunk's own keys under
    the sink+local mask, then the chunk is ring-inserted.  Computing
    attention *before* eviction is what makes chunks longer than the
    ring exact: mid-chunk queries still see keys the insert is about to
    overwrite."""
    B, C, _ = x.shape
    if isinstance(cache, KC.RingLatentKV):
        ckv, kr = A.mla_latent(bp["attn"], cfg, x, positions)
        q, _ = A.mla_q(bp["attn"], cfg, x, positions)
        k_ctx, v_ctx = A.mla_expand_kv(bp["attn"], cfg, cache.ckv, cache.kr)
        k_new, v_new = A.mla_expand_kv(bp["attn"], cfg, ckv, kr)
    else:
        q, k_new, v_new, _ = A.gqa_qkv(bp["attn"], cfg, x, positions)
        k_ctx, v_ctx = cache.k, cache.v
    kv_pos = jnp.concatenate(
        [cache.positions, jnp.broadcast_to(positions, (B, C))], axis=1)
    k_all = jnp.concatenate([k_ctx, k_new], axis=2)
    v_all = jnp.concatenate([v_ctx, v_new], axis=2)
    valid = M.streaming_valid(positions, kv_pos, sink, local)  # (B,C,L)
    o = M.masked_attention(q, k_all, v_all, valid[:, None])
    if isinstance(cache, KC.RingLatentKV):
        cache = KC.ring_latent_insert_chunk(cache, ckv, kr, start, sink,
                                            local)
        return A.mla_out(bp["attn"], cfg, o), cache
    cache = KC.ring_insert_chunk(cache, k_new, v_new, start, sink, local)
    return A.gqa_out(bp["attn"], cfg, o), cache


def _chunk_attn_full(bp, cfg: ModelConfig, x, positions, start, cache):
    """Chunk attention at a full-cache layer: insert the chunk at
    [start, start+C), then causal attention over the cache buffer via
    the kv-blocked online softmax (``modes.chunk_causal_attention``) —
    slots past the chunk hold zeros at positions > every query, and the
    traced block trip count never visits them."""
    B, C, _ = x.shape
    if isinstance(cache, KC.LatentKV):
        ckv, kr = A.mla_latent(bp["attn"], cfg, x, positions)
        cache = KC.latent_insert_chunk(cache, ckv, kr, start)
        Smax = cache.ckv.shape[1]
        valid = jnp.arange(Smax)[None, None, :] <= positions[None, :, None]
        y = A.mla_absorbed_attend(bp["attn"], cfg, x, positions, cache.ckv,
                                  cache.kr,
                                  jnp.broadcast_to(valid, (B, C, Smax)))
        return y, cache
    q, k_new, v_new, _ = A.gqa_qkv(bp["attn"], cfg, x, positions)
    cache = KC.full_insert_chunk(cache, k_new, v_new, start)
    # kv-blocked online softmax with a traced trip count: compute
    # scales with the live prefix [0, start+C), not the buffer
    o = M.chunk_causal_attention(q, cache.k, cache.v, start)
    return A.gqa_out(bp["attn"], cfg, o), cache


def prefill_chunk(params, cfg: ModelConfig, tokens: jax.Array, caches: List,
                  start: jax.Array, enc_out=None):
    """Stream one chunk of a chunked cache-resident prefill.

    tokens (B, C) int32 — the chunk (static, bucketed length C);
    ``start`` () int32 traced — its absolute offset; ``caches`` — the
    decode-geometry cache list being filled (routing already frozen:
    the pattern was fixed on the first chunk, §3.3).  Mamba layers
    thread their SSD state / conv tail through the same cache slots.
    Returns (last-token logits (B, V), updated caches).
    """
    B, C = tokens.shape
    flux = cfg.flux
    h = embed_tokens(params, cfg, tokens)
    positions = start + jnp.arange(C)
    new_caches = []
    for i, kind in enumerate(cfg.layer_kinds):
        bp = layer_params(params, cfg, i)
        cache = caches[i]
        x = rms_norm(bp["norm1"], h, cfg.norm_eps)
        if kind == "mamba":
            y, (hs, tail) = S.mamba_apply(bp["mamba"], cfg, x,
                                          (cache.h, cache.conv_tail))
            cache = KC.MambaCache(h=hs, conv_tail=tail)
            h = h + y
        else:
            if isinstance(cache, (KC.RingKV, KC.RingLatentKV)):
                sink = 0 if kind == "local" else flux.sink
                ring = (cache.ckv.shape[1]
                        if isinstance(cache, KC.RingLatentKV)
                        else cache.k.shape[2])
                y, cache = _chunk_attn_ring(bp, cfg, x, positions, start,
                                            cache, sink, ring - sink)
            else:
                y, cache = _chunk_attn_full(bp, cfg, x, positions, start,
                                            cache)
            h = h + y
            if "xattn" in bp and enc_out is not None:
                hx = rms_norm(bp["norm_x"], h, cfg.norm_eps)
                h = h + _cross_attention(bp["xattn"], cfg, hx, enc_out)
        if has_ffn(cfg, i):
            x2 = rms_norm(bp["norm2"], h, cfg.norm_eps)
            if "moe" in bp:
                y2, _ = MOE.moe_apply(bp["moe"], cfg, x2)
            else:
                y2 = ffn_apply(bp["ffn"], x2)
            h = h + y2
        new_caches.append(cache)
    logits = logits_from_hidden(params, cfg, h[:, -1])
    return logits, new_caches


def snapshot_state(caches, logits: jax.Array):
    """Bitwise copy of a chunk-boundary admission state — the per-layer
    decode-geometry cache pytree plus the boundary's last-token logits
    — into fresh buffers.  This is the snapshot the shared-prefix radix
    cache stores and restores (serve/prefix_cache.py): the chunked
    prefill and decode jits *donate* their cache buffers, so a snapshot
    must not alias them.  ``jnp.copy`` rather than an arithmetic
    identity: ``x + 0`` would flip ``-0.0`` sign bits and break the
    bitwise-exact reuse guarantee.  Under jit this compiles to one
    executable per cache geometry (the engine's restore jit, counted by
    its executable guard)."""
    return jax.tree.map(jnp.copy, caches), jnp.copy(logits)


def routing_head_split(cfg: ModelConfig, routing):
    """Translate a routing pattern into (fa_heads, duo_layers):
    the traced per-layer full-KV-head counts and the *static* tuple of
    duo layer indices — (None, None) when no entry needs a head split
    (pure geometry dispatch keeps the 1-D validity mask that
    kernel/distributed overrides expect on every layer)."""
    duo = tuple(i for i, r in enumerate(routing)
                if isinstance(r, tuple) and r[0] == "duo")
    if not duo:
        return None, None
    if cfg.use_mla:
        raise ValueError(
            "duo head-split routing requires per-KV-head GQA caches; "
            "MLA shares one latent across heads (cfg.use_mla=True) so "
            f"a split is meaningless — got duo at layers {duo}")
    fa_heads = jnp.asarray(
        [r[1] if isinstance(r, tuple) and r[0] == "duo"
         else cfg.num_kv_heads for r in routing], jnp.int32)
    return fa_heads, duo


def decode_step(params, cfg: ModelConfig, token: jax.Array, caches: List,
                routing: Tuple[str, ...], pos: jax.Array, enc_out=None):
    """One autoregressive step (pattern-tuple convenience wrapper).

    token (B,1) int32; ``routing`` is the per-layer pattern
    ("fa" | "sa" | ("duo", n) | None) cached from prefill (§3.3 — the
    router runs once).  The fa/sa entries are *informational* here: the
    cache geometry built by ``repack_caches``/``init_decode_caches``
    already encodes them, and ``decode_core`` dispatches on it — only
    duo head splits survive (split counts as traced data, the duo
    layer set as static structure).
    Returns (logits (B,V), new_caches).
    """
    fa_heads, duo_layers = routing_head_split(cfg, routing)
    return decode_core(params, cfg, token, caches, pos, enc_out=enc_out,
                       fa_heads=fa_heads, duo_layers=duo_layers)


def decode_many(params, cfg: ModelConfig, logits: jax.Array, caches: List,
                pos: jax.Array, rng: jax.Array, *, n_steps: int,
                greedy: bool = True, enc_out=None, fa_heads=None,
                duo_layers: Optional[Tuple[int, ...]] = None,
                unroll: int = 4):
    """Fused generation: sample → decode for ``n_steps`` in one
    ``lax.scan``, entirely on device.

    logits (B,V): next-token logits from prefill (or a previous chunk);
    pos () or (B,) int32: absolute position of the first generated
    token — per-slot when rows are independent requests in a
    continuous-batching pool; rng: PRNG key (ignored when ``greedy``).  Under jit, mark ``n_steps``,
    ``greedy`` and ``unroll`` static and donate ``caches`` so every
    cache append is an in-place ``dynamic_update_slice`` on the
    original buffers — no per-step host sync, no per-step cache copy.
    ``unroll`` trades compile time for cross-step fusion inside the
    scan (semantics are unchanged — same per-step graph, repeated).

    Returns (tokens (B, n_steps) int32, last logits (B,V), caches).
    Token i is sampled from the logits *before* decode step i, exactly
    matching a per-step sample→decode python loop.
    """
    def step(carry, _):
        logits, caches, pos, rng = carry
        if greedy:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            rng, k = jax.random.split(rng)
            nxt = jax.random.categorical(k, logits).astype(jnp.int32)
        logits, caches = decode_core(params, cfg, nxt[:, None], caches,
                                     pos, enc_out=enc_out,
                                     fa_heads=fa_heads,
                                     duo_layers=duo_layers)
        return (logits, caches, pos + 1, rng), nxt

    (logits, caches, _, _), toks = lax.scan(
        step, (logits, caches, jnp.asarray(pos, jnp.int32), rng),
        length=n_steps, unroll=max(1, min(unroll, n_steps)))
    return jnp.moveaxis(toks, 0, 1), logits, caches


# ---------------------------------------------------------------------------
# Diagnostics
# ---------------------------------------------------------------------------

def capture_hidden(params, cfg: ModelConfig, tokens: jax.Array,
                   prefix_embeddings=None, encoder_frames=None) -> jax.Array:
    """Hidden states after every layer (L, B, S_total, d) — used by the
    UnComp entropy ranking (paper App. C) and analysis benches."""
    enc_out = (encode(params, cfg, encoder_frames)
               if cfg.num_encoder_layers else None)
    h = embed_tokens(params, cfg, tokens, prefix_embeddings)
    positions = jnp.arange(h.shape[1])
    P = period_len(cfg)

    def body(carry, xs):
        h = carry
        _, trunk_slice = xs
        snaps = []
        for pos in range(P):
            h, _, _, _ = block_apply(trunk_slice[pos], cfg, pos, h,
                                     positions, ("fa_only",),
                                     enc_out=enc_out)
            snaps.append(h)
        return h, jnp.stack(snaps)

    xs = (jnp.arange(n_periods(cfg)), params["trunk"])
    _, snaps = lax.scan(body, h, xs)  # (n_periods, P, B, S, d)
    return snaps.reshape(cfg.num_layers, *snaps.shape[2:])


def attention_mass_coverage(params, cfg: ModelConfig, tokens: jax.Array,
                            *, length=None, prefix_embeddings=None,
                            encoder_frames=None) -> jax.Array:
    """Per-routed-layer FA attention-mass retained by the SA window —
    the serving stack's routing-fidelity probe (DESIGN.md
    §Observability).

    Runs an FA-only forward and, at every routed layer, asks: of the
    full-attention softmax mass the *last* live query spreads over the
    prefix, what fraction lands on keys the SA mode would have kept?
    1.0 means routing this layer to SA loses nothing for the next
    decoded token; low coverage means the router is trading real
    attention mass away.  Exact for the streaming (ssa) mode; for
    triangle the last query sits in the dense tail chunk so coverage is
    exactly 1; for block_topk the sink+local window is a conservative
    lower bound (the selector keeps at least the forced sink/diagonal
    blocks).

    ``tokens`` may be padded past the real prompt: ``length`` (a
    *traced* scalar) marks the live prefix, and causal masking makes
    the padded forward exact for positions < length — the engine pads
    probe prompts to a power-of-two bucket so probing adds O(log
    max_len) executables, not one per prompt length.

    Returns (n_routed,) float32 in ``cfg.routable_layers()`` order.
    """
    enc_out = (encode(params, cfg, encoder_frames)
               if cfg.num_encoder_layers else None)
    h = embed_tokens(params, cfg, tokens, prefix_embeddings)
    S = h.shape[1]
    positions = jnp.arange(S)
    P = period_len(cfg)
    if not any(is_routed(cfg, pos) for pos in range(P)):
        return jnp.zeros((0,), jnp.float32)
    length = jnp.asarray(S if length is None else length, jnp.int32)
    q_idx = length - 1
    sa = sa_mode(cfg)
    kv_pos = jnp.arange(S)
    live = kv_pos < length
    if sa.kind == "triangle":
        vis = live  # dense tail chunk: the last query sees everything
    else:
        vis = live & ((kv_pos < sa.sink) | (q_idx - kv_pos < sa.local))

    def body(carry, xs):
        h = carry
        _, trunk_slice = xs
        covs = []
        for pos in range(P):
            bp = trunk_slice[pos]
            if is_routed(cfg, pos):
                # duplicate the (cheap) qk projection rather than thread
                # probe plumbing through block_apply's cache contract
                x = rms_norm(bp["norm1"], h, cfg.norm_eps)
                if cfg.use_mla:
                    ckv, kr = A.mla_latent(bp["attn"], cfg, x, positions)
                    q, _ = A.mla_q(bp["attn"], cfg, x, positions)
                    k, _ = A.mla_expand_kv(bp["attn"], cfg, ckv, kr)
                else:
                    q, k, _, _ = A.gqa_qkv(bp["attn"], cfg, x, positions)
                    G = q.shape[1] // k.shape[1]
                    if G > 1:  # kv-major head order, as in M._gqa_view
                        k = jnp.repeat(k, G, axis=1)
                q_last = jnp.take(q, q_idx, axis=2)  # (B, H, D)
                s = jnp.einsum("bhd,bhsd->bhs", q_last, k,
                               preferred_element_type=jnp.float32)
                s = s * (q.shape[-1] ** -0.5)
                s = jnp.where(live[None, None, :], s, M.NEG_INF)
                p = jax.nn.softmax(s, axis=-1)
                covs.append(jnp.mean(
                    jnp.sum(jnp.where(vis[None, None, :], p, 0.0),
                            axis=-1)))
            h, _, _, _ = block_apply(bp, cfg, pos, h, positions,
                                     ("fa_only",), enc_out=enc_out)
        return h, jnp.stack(covs)

    xs = (jnp.arange(n_periods(cfg)), params["trunk"])
    _, covs = lax.scan(body, h, xs)  # (n_periods, n_routed_per_period)
    return covs.reshape(-1)
