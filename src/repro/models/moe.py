"""Mixture-of-Experts FFN with capacity-bounded scatter dispatch.

Production formulation (MaxText/Megablocks-style "dropping" path):
tokens pick top-k experts; each expert has a static capacity
C = ceil(T·k/E · capacity_factor); tokens are scattered into an
(E, C, D) buffer, expert FFNs run as one batched einsum with the expert
dim sharded over the ``model`` mesh axis (expert parallelism) when
E % model_size == 0, and gathered back weighted by the (renormalized)
router probabilities.  Overflow tokens are dropped (residual connection
carries them), underflow slots are zero — standard capacity semantics.

Shared experts (DeepSeek-V2) are plain dense SwiGLUs applied to every
token and added to the routed output.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import constrain
from repro.models.layers import dense_init, ffn_apply, ffn_init


def moe_init(key, cfg: ModelConfig) -> Dict[str, jax.Array]:
    kg, ke, ks = jax.random.split(key, 3)
    d, dt, E, f = cfg.d_model, cfg.param_dtype, cfg.num_experts, cfg.moe_d_ff
    keys = jax.random.split(ke, 3)
    params = {
        "gate_w": dense_init(kg, d, E, jnp.float32),
        "experts": {
            "gate": jax.vmap(lambda k: dense_init(k, d, f, dt))(
                jax.random.split(keys[0], E)),
            "up": jax.vmap(lambda k: dense_init(k, d, f, dt))(
                jax.random.split(keys[1], E)),
            "down": jax.vmap(lambda k: dense_init(k, f, d, dt))(
                jax.random.split(keys[2], E)),
        },
    }
    if cfg.num_shared_experts:
        params["shared"] = ffn_init(
            ks, d, cfg.moe_d_ff * cfg.num_shared_experts, cfg.param_dtype)
    return params


def moe_apply(params, cfg: ModelConfig, x: jax.Array,
              capacity_factor: Optional[float] = None
              ) -> Tuple[jax.Array, Dict]:
    """x (B,S,D) → (y (B,S,D), aux diagnostics).

    GROUPED dispatch (MaxText-style): capacity and scatter positions are
    computed per batch row, so every tensor keeps a leading batch dim
    that shards over ("pod","data") and dispatch never crosses data
    shards.  (A global-cumsum dispatch makes the slot position of every
    token depend on every other shard's counts — observed as three
    64 GB expert-buffer all-gathers per MoE layer on the 256-chip mesh;
    EXPERIMENTS.md §Perf iteration 4.)
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k

    # Dispatch must be row-local: under Megatron-SP rules the residual
    # stream is *sequence*-sharded, and a cumsum along a sharded dim
    # forces SPMD to gather every (B, S·K, D) dispatch tensor
    # (≈0.5 TB/step for deepseek-v2 — EXPERIMENTS.md §Perf it. 4b).
    # Un-shard the seq dim here; batch stays sharded.
    x = constrain(x, "batch", None, None)

    logits = (x.astype(jnp.float32) @ params["gate_w"])  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, K)  # (B,S,K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    cap = (capacity_factor if capacity_factor is not None
           else cfg.moe_capacity_factor)
    # Per-row capacity; max per-expert load within a row is S (top-k
    # experts are distinct per token), so C == S is dropless.
    C = min(max(1, int(-(-S * K // E) * cap)), S)

    # slot position within (row, expert): one-hot cumsum along the
    # row-local flattened (S·K) order — token-priority, shard-local.
    oh = jax.nn.one_hot(top_i.reshape(B, S * K), E,
                        dtype=jnp.int32)  # (B, S*K, E)
    pos_all = jnp.cumsum(oh, axis=1) - oh
    e_flat = top_i.reshape(B, S * K)
    pos = jnp.take_along_axis(pos_all, e_flat[..., None],
                              axis=2)[..., 0]  # (B, S*K)
    keep = pos < C
    w_flat = jnp.where(keep, top_p.reshape(B, S * K), 0.0)

    # Scatter tokens into (B, E, C, D) — batched over rows.
    src = jnp.repeat(x, K, axis=1)  # (B, S*K, D): slot s*K+j ← token s
    expert_in = jnp.zeros((B, E, C, D), x.dtype)
    b_idx = jnp.arange(B)[:, None]
    expert_in = expert_in.at[
        b_idx, e_flat, jnp.where(keep, pos, C - 1)].add(
        src * keep[..., None].astype(x.dtype))
    # E must stay UNsharded: the scatter/gather index it; tensor
    # parallelism lives on the expert hidden dim instead (weights are
    # f-sharded over "model", E replicated — see launch/shardings).
    expert_in = constrain(expert_in, "batch", None, None, None)

    ew = params["experts"]
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", expert_in, ew["gate"])
                    ) * jnp.einsum("becd,edf->becf", expert_in, ew["up"])
    h = constrain(h, "batch", None, None, "ffn")
    expert_out = jnp.einsum("becf,efd->becd", h, ew["down"])
    expert_out = constrain(expert_out, "batch", None, None, None)

    # Gather back per row: slot reads expert_out[b, e, pos].
    gathered = expert_out[b_idx, e_flat,
                          jnp.where(keep, pos, 0)]  # (B, S*K, D)
    y = (gathered * w_flat[..., None].astype(x.dtype)
         ).reshape(B, S, K, D).sum(axis=2)

    if "shared" in params:
        y = y + ffn_apply(params["shared"], x)

    load = oh.sum((0, 1))
    frac_tokens = load.astype(jnp.float32) / jnp.maximum(load.sum(), 1)
    mean_prob = probs.mean((0, 1))
    aux = {
        "load": load,                            # tokens per expert (pre-cap)
        "drop_fraction": 1.0 - keep.mean(),
        "router_entropy": -(probs * jnp.log(probs + 1e-9)).sum(-1).mean(),
        # Switch-style load-balance loss (used when pretraining backbones).
        "balance_loss": E * jnp.sum(frac_tokens * mean_prob),
    }
    return y, aux
