"""Attention layers: GQA (dense archs) and MLA (DeepSeek-V2).

Each layer exposes three stages so the Flux wrapper can compute Q/K/V
once and run both the FA and SA modes over them during soft routing:

    *_qkv    — projections (+RoPE); also returns the flat query tensor
               x_Q fed to the Layer Router (paper §3.1).
    attention modes run via ``repro.core.modes``.
    *_out    — output projection.

MLA additionally returns the compressed KV latent (+ shared roped key)
— that is what the serving layer caches (DESIGN.md: the SA ring cache
stores the 512-d latent, making sparse layers even cheaper).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import constrain
from repro.models.layers import apply_rope, dense_init, rms_norm, rms_norm_init


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_init(key, cfg: ModelConfig) -> Dict[str, jax.Array]:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, dt = cfg.d_model, cfg.param_dtype
    return {
        "wq": dense_init(k1, d, cfg.q_dim, dt),
        "wk": dense_init(k2, d, cfg.kv_dim, dt),
        "wv": dense_init(k3, d, cfg.kv_dim, dt),
        "wo": dense_init(k4, cfg.q_dim, d, dt),
    }


def gqa_qkv(params, cfg: ModelConfig, x: jax.Array, positions: jax.Array
            ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """x (B,S,d) → q (B,H,S,hd), k/v (B,Hkv,S,hd), x_Q (B,S,q_dim)."""
    B, S, _ = x.shape
    x_q = x @ params["wq"]
    q = x_q.reshape(B, S, cfg.num_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    k = (x @ params["wk"]).reshape(B, S, cfg.num_kv_heads, cfg.head_dim
                                   ).transpose(0, 2, 1, 3)
    v = (x @ params["wv"]).reshape(B, S, cfg.num_kv_heads, cfg.head_dim
                                   ).transpose(0, 2, 1, 3)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "heads", None, None)
    k = constrain(k, "batch", "kv_heads", None, None)
    v = constrain(v, "batch", "kv_heads", None, None)
    return q, k, v, x_q


def gqa_out(params, cfg: ModelConfig, attn: jax.Array) -> jax.Array:
    """attn (B,H,S,hd) → (B,S,d)."""
    B, H, S, hd = attn.shape
    y = attn.transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    return constrain(y @ params["wo"], "batch", None, "embed")


# ---------------------------------------------------------------------------
# MLA (Multi-head Latent Attention, DeepSeek-V2)
# ---------------------------------------------------------------------------

def mla_init(key, cfg: ModelConfig) -> Dict[str, jax.Array]:
    ks = jax.random.split(key, 6)
    d, dt = cfg.d_model, cfg.param_dtype
    qk_hd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    return {
        "w_dq": dense_init(ks[0], d, cfg.q_lora_rank, dt),
        "q_norm": rms_norm_init(cfg.q_lora_rank, dt),
        "w_uq": dense_init(ks[1], cfg.q_lora_rank, cfg.num_heads * qk_hd, dt),
        "w_dkv": dense_init(ks[2], d, cfg.kv_lora_rank, dt),
        "kv_norm": rms_norm_init(cfg.kv_lora_rank, dt),
        "w_kr": dense_init(ks[3], d, cfg.qk_rope_head_dim, dt),
        "w_ukv": dense_init(
            ks[4], cfg.kv_lora_rank,
            cfg.num_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim), dt),
        "wo": dense_init(ks[5], cfg.num_heads * cfg.v_head_dim, d, dt),
    }


def mla_latent(params, cfg: ModelConfig, x: jax.Array, positions: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    """Compressed KV: latent (B,S,R) (normed) + shared roped key
    (B,1,S,rope_dim).  This pair is what gets cached."""
    ckv = rms_norm(params["kv_norm"], x @ params["w_dkv"], cfg.norm_eps)
    k_rope = (x @ params["w_kr"])[:, None]  # single shared head
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return ckv, k_rope


def mla_q(params, cfg: ModelConfig, x: jax.Array, positions: jax.Array
          ) -> Tuple[jax.Array, jax.Array]:
    """q (B,H,S,nope+rope) and the router input x_Q (B,S,H·(nope+rope))."""
    B, S, _ = x.shape
    q_lat = rms_norm(params["q_norm"], x @ params["w_dq"], cfg.norm_eps)
    x_q = q_lat @ params["w_uq"]
    qk_hd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    q = x_q.reshape(B, S, cfg.num_heads, qk_hd).transpose(0, 2, 1, 3)
    q_nope = q[..., :cfg.qk_nope_head_dim]
    q_rope = apply_rope(q[..., cfg.qk_nope_head_dim:], positions,
                        cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    return constrain(q, "batch", "heads", None, None), x_q


def mla_expand_kv(params, cfg: ModelConfig, ckv: jax.Array,
                  k_rope: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Decompress latent → per-head K (B,H,S,nope+rope), V (B,H,S,v)."""
    B, S, _ = ckv.shape
    H = cfg.num_heads
    kv = (ckv @ params["w_ukv"]).reshape(
        B, S, H, cfg.qk_nope_head_dim + cfg.v_head_dim).transpose(0, 2, 1, 3)
    k_nope, v = (kv[..., :cfg.qk_nope_head_dim],
                 kv[..., cfg.qk_nope_head_dim:])
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, H, S, cfg.qk_rope_head_dim))],
        axis=-1)
    return (constrain(k, "batch", "heads", None, None),
            constrain(v, "batch", "heads", None, None))


def mla_out(params, cfg: ModelConfig, attn: jax.Array) -> jax.Array:
    B, H, S, dv = attn.shape
    y = attn.transpose(0, 2, 1, 3).reshape(B, S, H * dv)
    return constrain(y @ params["wo"], "batch", None, "embed")


def mla_absorbed_attend(params, cfg: ModelConfig, x: jax.Array,
                        positions: jax.Array, ckv_cache: jax.Array,
                        kr_cache: jax.Array, valid: jax.Array) -> jax.Array:
    """Weight-absorbed MLA attention over a latent cache (DESIGN.md §2).

    Scores are computed directly in latent space — W_uk is absorbed into
    the query and W_uv into the output projection, so the cost is
    O(Sq·S·(R+rope)·H) instead of decompressing S latents per head.
    Serves both the single-token decode step (Sq=1) and the chunked
    prefill's full-latent layers (Sq = chunk).

    x (B,Sq,d); ckv_cache (B,S,R); kr_cache (B,1,S,rope);
    valid (B,Sq,S) bool.  Returns (B,Sq,d).
    """
    B = x.shape[0]
    H, R = cfg.num_heads, cfg.kv_lora_rank
    nope, rope, dv = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                      cfg.v_head_dim)
    q, _ = mla_q(params, cfg, x, positions)  # (B,H,Sq,nope+rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    # Absorb W_uk: per head, w_uk (R, nope) ⇒ q_lat = q_nope @ w_uk^T (R,)
    w_ukv = params["w_ukv"].reshape(R, H, nope + dv)
    w_uk = w_ukv[:, :, :nope]   # (R,H,nope)
    w_uv = w_ukv[:, :, nope:]   # (R,H,dv)
    q_lat = jnp.einsum("bhqn,rhn->bhqr", q_nope, w_uk)  # (B,H,Sq,R)
    scores = jnp.einsum("bhqr,bsr->bhqs", q_lat, ckv_cache,
                        preferred_element_type=jnp.float32)
    scores += jnp.einsum("bhqe,bzse->bhqs", q_rope, kr_cache,
                         preferred_element_type=jnp.float32)
    scores *= (nope + rope) ** -0.5
    scores = jnp.where(valid[:, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqs,bsr->bhqr", p.astype(ckv_cache.dtype), ckv_cache)
    attn = jnp.einsum("bhqr,rhv->bhqv", ctx, w_uv)  # (B,H,Sq,dv)
    return mla_out(params, cfg, attn)


def mla_absorbed_decode(params, cfg: ModelConfig, x: jax.Array,
                        position: jax.Array, ckv_cache: jax.Array,
                        kr_cache: jax.Array, valid: jax.Array) -> jax.Array:
    """Single-token absorbed decode: x (B,1,d), valid (B,S) → (B,1,d)."""
    return mla_absorbed_attend(params, cfg, x, position, ckv_cache,
                               kr_cache, valid[:, None, :])


def mla_absorbed_qkv(params, cfg: ModelConfig, x: jax.Array,
                     position: jax.Array, ckv_cache: jax.Array,
                     kr_cache: jax.Array
                     ) -> Tuple[jax.Array, jax.Array, jax.Array, float]:
    """Re-express absorbed MLA decode as a GQA-shaped (q, k, v, scale).

    The absorbed score  q_lat·ckv + q_rope·kr  is an inner product over
    the concatenated (R + rope) axis, so a flash-decode kernel that
    only speaks q·kᵀ can run it verbatim with
      q_eff = [q_lat ‖ q_rope]           (B, H, 1, R+rope)
      k_eff = [ckv ‖ kr]                 (B, 1, S, R+rope)   (Hkv = 1)
      v_eff = ckv                        (B, 1, S, R)
    The kernel's softmax(scores)·v_eff then yields the latent context
    ctx (B, H, 1, R); ``mla_absorbed_finish`` applies the absorbed
    W_uv and output projection.  Note Dk = R+rope ≠ Dv = R.
    """
    B = x.shape[0]
    H, R = cfg.num_heads, cfg.kv_lora_rank
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    dv = cfg.v_head_dim
    q, _ = mla_q(params, cfg, x, position)  # (B,H,1,nope+rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    w_uk = params["w_ukv"].reshape(R, H, nope + dv)[:, :, :nope]
    q_lat = jnp.einsum("bhqn,rhn->bhqr", q_nope, w_uk)  # (B,H,1,R)
    q_eff = jnp.concatenate([q_lat, q_rope], axis=-1)
    S = ckv_cache.shape[1]
    k_eff = jnp.concatenate(
        [ckv_cache[:, None],
         jnp.broadcast_to(kr_cache, (B, 1, S, rope))], axis=-1)
    v_eff = ckv_cache[:, None]
    return q_eff, k_eff, v_eff, (nope + rope) ** -0.5


def mla_absorbed_finish(params, cfg: ModelConfig,
                        ctx: jax.Array) -> jax.Array:
    """Latent context ctx (B,H,1,R) → output projection (B,1,d)."""
    H, R = cfg.num_heads, cfg.kv_lora_rank
    nope, dv = cfg.qk_nope_head_dim, cfg.v_head_dim
    w_uv = params["w_ukv"].reshape(R, H, nope + dv)[:, :, nope:]
    attn = jnp.einsum("bhqr,rhv->bhqv", ctx, w_uv)  # (B,H,1,dv)
    return mla_out(params, cfg, attn)
