"""Shared building blocks: RMSNorm, RoPE, SwiGLU, linear init."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype) -> jax.Array:
    scale = 1.0 / jnp.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02
            ).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rms_norm_init(dim: int, dtype) -> Dict[str, jax.Array]:
    return {"scale": jnp.ones((dim,), dtype)}


def rms_norm(params: Dict[str, jax.Array], x: jax.Array,
             eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (half-rotation / llama style)
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim // 2,)."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate ``x`` (..., S, D) by position-dependent angles.

    ``positions`` broadcasts against the S axis, e.g. shape (S,) or (B, S)
    against (B, H, S, D).
    """
    d = x.shape[-1]
    inv_freq = rope_frequencies(d, theta)  # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (..., S, d/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    # Broadcast (..., S, d/2) against x (..., H, S, d/2): add head axis if
    # positions lacked it.
    while sin.ndim < x.ndim:
        sin = sin[..., None, :, :]
        cos = cos[..., None, :, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU FFN
# ---------------------------------------------------------------------------

def ffn_init(key, d_model: int, d_ff: int, dtype) -> Dict[str, jax.Array]:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d_model, d_ff, dtype),
        "up": dense_init(k2, d_model, d_ff, dtype),
        "down": dense_init(k3, d_ff, d_model, dtype),
    }


def ffn_apply(params: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ params["gate"]) * (x @ params["up"])
    return h @ params["down"]
