"""Training loops.

``RouterTrainer`` reproduces the paper's parameter-efficient recipe
(§3.2, App. D): the backbone is frozen, only Layer-Router parameters
train (lr 5e-4), the Lagrange multipliers λ₁, λ₂ are *ascended*
(lr 1e-3) and projected to ≥0, the Gumbel temperature anneals linearly,
and the loss is CE + λ₁·L_diff + λ₂·L_diff² per task type (Eq. 6).

``PretrainTrainer`` trains all parameters (used to build the small
backbones our accuracy benches evaluate — the paper starts from
pretrained Qwen/Llama checkpoints which are not available offline).

``ContinuedTrainer`` freezes the *router* and trains the backbone
(paper §5.3 backbone-adaptation experiment).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import router as R
from repro.core import sparsity as SP
from repro.data.synthetic import Batch
from repro.models import model as MD
from repro.train import optimizer as OPT


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def _sum_aux(aux: Dict[str, jax.Array], key: str) -> jax.Array:
    v = aux.get(key)
    return jnp.sum(v) if v is not None else jnp.float32(0.0)


def chunked_cross_entropy(hidden: jax.Array, w: jax.Array,
                          labels: jax.Array, mask: jax.Array,
                          chunk: int = 512) -> jax.Array:
    """CE computed per sequence chunk — the (B,S,V) logits tensor is
    never materialized (at 256k vocab it would dominate memory)."""
    B, S, d = hidden.shape
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = hidden.shape[1] // c
    hs = jnp.moveaxis(hidden.reshape(B, nc, c, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, nc, c), 1, 0)
    ms = jnp.moveaxis(mask.reshape(B, nc, c), 1, 0)

    def body(carry, xs):
        hc, lc, mc = xs
        logits = (hc @ w.astype(hc.dtype)).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, lc[..., None], axis=-1)[..., 0]
        return carry - (ll * mc).sum(), None

    total, _ = jax.lax.scan(body, jnp.float32(0.0), (hs, ls, ms))
    return total / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# Router training (the paper's recipe)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RouterTrainer:
    cfg: ModelConfig
    total_steps: int
    lr_router: float = 5e-4      # paper: Mask LR 5e-4
    lr_lagrange: float = 1e-3    # paper: Reg LR 1e-3
    weight_decay: float = 0.1

    def init(self, params, key=None):
        mask = MD.router_param_filter(params)
        trainable, frozen = OPT.partition(params, mask)
        lagrange = SP.lagrangian_init(self.cfg.flux, key)
        return {
            "trainable": trainable,
            "frozen": frozen,
            "lagrange": lagrange,
            "opt_router": OPT.adamw_init(trainable),
            "opt_lagrange": OPT.adamw_init(lagrange),
            "step": jnp.zeros((), jnp.int32),
        }

    def params(self, state) -> Any:
        return OPT.combine(state["trainable"], state["frozen"])

    @partial(jax.jit, static_argnums=0)
    def step(self, state, tokens, labels, loss_mask, task_type, rng):
        return self.step_impl(state, tokens, labels, loss_mask, task_type,
                              rng)

    def step_impl(self, state, tokens, labels, loss_mask, task_type, rng,
                  prefix_embeddings=None, encoder_frames=None):
        cfg = self.cfg
        routed = bool(cfg.routable_layers()) and cfg.flux.enabled
        tau = R.anneal_tau(cfg.flux, state["step"], self.total_steps)
        lr_r = OPT.cosine_warmup(self.lr_router, self.total_steps)(
            state["step"])
        lr_l = OPT.cosine_warmup(self.lr_lagrange, self.total_steps)(
            state["step"])

        def loss_fn(trainable, lagrange):
            params = OPT.combine(trainable, state["frozen"])
            out = MD.forward_train(params, cfg, tokens, rng=rng, tau=tau,
                                   output_hidden=True,
                                   prefix_embeddings=prefix_embeddings,
                                   encoder_frames=encoder_frames)
            ce = chunked_cross_entropy(
                out.logits, MD.unembed_matrix(params, cfg), labels,
                loss_mask)
            if routed:
                sp, diag = SP.sparsity_loss(out.r_soft, task_type, lagrange,
                                            cfg.flux)
                soft_msr = jnp.mean(1.0 - out.r_soft)
                l_diff = diag["l_diff"]
                per_task = diag["per_task_sparsity"]
            else:  # e.g. attention-free SSM: nothing to route
                sp = jnp.float32(0.0)
                soft_msr = jnp.float32(jnp.nan)
                n = cfg.flux.num_task_types
                l_diff = per_task = jnp.zeros((n,), jnp.float32)
            loss = ce + sp
            metrics = {
                "loss": loss, "ce": ce, "sparsity_loss": sp,
                "soft_msr": soft_msr,
                "l_diff": l_diff,
                "per_task_sparsity": per_task,
                "tau": tau,
            }
            return loss, metrics

        (loss, metrics), (g_router, g_lagrange) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True)(
                state["trainable"], state["lagrange"])
        new_trainable, opt_r = OPT.adamw_update(
            g_router, state["opt_router"], state["trainable"], lr=lr_r,
            weight_decay=self.weight_decay)
        # max over λ: ascent + projection to λ ≥ 0
        new_lagrange, opt_l = OPT.adamw_update(
            g_lagrange, state["opt_lagrange"], state["lagrange"], lr=lr_l,
            ascend=True)
        new_lagrange = SP.project_lagrange(new_lagrange)
        metrics["lambda1"] = new_lagrange["lambda1"]
        metrics["lambda2"] = new_lagrange["lambda2"]
        new_state = {
            "trainable": new_trainable, "frozen": state["frozen"],
            "lagrange": new_lagrange, "opt_router": opt_r,
            "opt_lagrange": opt_l, "step": state["step"] + 1,
        }
        return new_state, metrics

    def run(self, state, data_iter, steps: int, log_every: int = 50,
            seed: int = 0, log_fn=print):
        key = jax.random.key(seed)
        history = []
        for i in range(steps):
            b: Batch = next(data_iter)
            key, sub = jax.random.split(key)
            state, m = self.step(state, jnp.asarray(b.tokens),
                                 jnp.asarray(b.labels),
                                 jnp.asarray(b.loss_mask),
                                 jnp.asarray(b.task_type), sub)
            if i % log_every == 0 or i == steps - 1:
                rec = {k: np.asarray(v).tolist() for k, v in m.items()}
                rec["step"] = i
                history.append(rec)
                log_fn(f"[router {i:5d}] loss={rec['loss']:.4f} "
                       f"ce={rec['ce']:.4f} msr={rec['soft_msr']:.3f} "
                       f"tau={rec['tau']:.2f}")
        return state, history


# ---------------------------------------------------------------------------
# Backbone pretraining (substrate for the accuracy benches)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PretrainTrainer:
    cfg: ModelConfig
    total_steps: int
    lr: float = 3e-4
    weight_decay: float = 0.1
    moe_balance_coef: float = 0.01
    flux_soft: bool = False  # joint backbone+router training if True

    def init(self, params):
        return {"params": params, "opt": OPT.adamw_init(params),
                "step": jnp.zeros((), jnp.int32)}

    @partial(jax.jit, static_argnums=0)
    def step(self, state, tokens, labels, loss_mask, rng):
        cfg = self.cfg
        lr = OPT.cosine_warmup(self.lr, self.total_steps, 0.05)(
            state["step"])

        def loss_fn(params):
            out = MD.forward_train(params, cfg, tokens, rng=rng,
                                   flux_soft=self.flux_soft, tau=1.0)
            ce = cross_entropy(out.logits, labels, loss_mask)
            bal = _sum_aux(out.aux, "moe_balance")
            return ce + self.moe_balance_coef * bal, {"ce": ce, "bal": bal}

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        new_params, opt = OPT.adamw_update(
            grads, state["opt"], state["params"], lr=lr,
            weight_decay=self.weight_decay)
        metrics["loss"] = loss
        return ({"params": new_params, "opt": opt,
                 "step": state["step"] + 1}, metrics)

    def run(self, state, data_iter, steps: int, log_every: int = 50,
            seed: int = 0, log_fn=print):
        key = jax.random.key(seed)
        history = []
        for i in range(steps):
            b: Batch = next(data_iter)
            key, sub = jax.random.split(key)
            state, m = self.step(state, jnp.asarray(b.tokens),
                                 jnp.asarray(b.labels),
                                 jnp.asarray(b.loss_mask), sub)
            if i % log_every == 0 or i == steps - 1:
                rec = {k: float(np.asarray(v)) for k, v in m.items()}
                rec["step"] = i
                history.append(rec)
                log_fn(f"[pretrain {i:5d}] loss={rec['loss']:.4f} "
                       f"ce={rec['ce']:.4f}")
        return state, history


# ---------------------------------------------------------------------------
# Continued training with a frozen router (paper §5.3)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ContinuedTrainer:
    """Backbone adapts to the router's (fixed) sparse pathways."""
    cfg: ModelConfig
    total_steps: int
    lr: float = 1e-4

    def init(self, params):
        mask = MD.router_param_filter(params)
        router, backbone = OPT.partition(params, mask)
        return {"backbone": backbone, "router": router,
                "opt": OPT.adamw_init(backbone),
                "step": jnp.zeros((), jnp.int32)}

    @partial(jax.jit, static_argnums=0)
    def step(self, state, tokens, labels, loss_mask, rng):
        cfg = self.cfg
        lr = OPT.cosine_warmup(self.lr, self.total_steps, 0.1)(state["step"])

        def loss_fn(backbone):
            params = OPT.combine(state["router"], backbone)
            # Router frozen; routing still soft at a fixed low tau so the
            # learned allocation shapes the gradients.
            out = MD.forward_train(params, cfg, tokens, rng=rng,
                                   tau=cfg.flux.tau_end)
            ce = cross_entropy(out.logits, labels, loss_mask)
            return ce, {"ce": ce}

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["backbone"])
        new_backbone, opt = OPT.adamw_update(
            grads, state["opt"], state["backbone"], lr=lr)
        return ({"backbone": new_backbone, "router": state["router"],
                 "opt": opt, "step": state["step"] + 1}, metrics)

    def params(self, state):
        return OPT.combine(state["router"], state["backbone"])
