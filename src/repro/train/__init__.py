from repro.train import checkpoint, optimizer  # noqa: F401
from repro.train.train_loop import (  # noqa: F401
    ContinuedTrainer,
    PretrainTrainer,
    RouterTrainer,
    cross_entropy,
)
