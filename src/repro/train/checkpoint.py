"""msgpack pytree checkpointing (no external deps beyond msgpack)."""
from __future__ import annotations

import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # bfloat16 et al. (ships with jax)
    import ml_dtypes

    def _np_dtype(name: str):
        try:
            return np.dtype(name)
        except TypeError:
            return np.dtype(getattr(ml_dtypes, name))
except ImportError:  # pragma: no cover
    def _np_dtype(name: str):
        return np.dtype(name)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save(path: str, tree: Any) -> None:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    payload = {}
    for p, leaf in flat:
        arr = np.asarray(leaf)
        payload[_path_str(p)] = {
            b"dtype": str(arr.dtype).encode(),
            b"shape": list(arr.shape),
            b"data": arr.tobytes(),
        }
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def load(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (same paths required)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=True)
    payload = {(k.decode() if isinstance(k, bytes) else k): v
               for k, v in payload.items()}
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat:
        key = _path_str(p)
        if key not in payload:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        rec = payload[key]
        arr = np.frombuffer(
            rec[b"data"], dtype=_np_dtype(rec[b"dtype"].decode())
        ).reshape(rec[b"shape"])
        leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
