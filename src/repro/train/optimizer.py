"""AdamW with decoupled weight decay + schedules + ascent groups.

Self-contained (no optax).  Supports:
  * pytree masking — only leaves marked trainable carry state/updates;
  * gradient-*ascent* groups (the Lagrange multipliers of Eq. 6 are
    maximized: sign-flipped update + projection to λ ≥ 0);
  * cosine decay with linear warmup (paper App. D: warmup 20%).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.tree_util import register_dataclass


# ---------------------------------------------------------------------------
# Pytree partitioning (trainable vs frozen)
# ---------------------------------------------------------------------------

def partition(tree, mask):
    """Split by boolean mask tree → (trainable, frozen); None elsewhere."""
    train = jax.tree.map(lambda m, x: x if m else None, mask, tree)
    frozen = jax.tree.map(lambda m, x: None if m else x, mask, tree)
    return train, frozen


def combine(a, b):
    """Inverse of ``partition`` (None-aware merge)."""
    return jax.tree.map(lambda x, y: x if x is not None else y, a, b,
                        is_leaf=lambda z: z is None)


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def cosine_warmup(base_lr: float, total_steps: int,
                  warmup_frac: float = 0.2,
                  final_frac: float = 0.05) -> Callable:
    warmup = max(1, int(total_steps * warmup_frac))

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / warmup
        t = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0, 1)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, base_lr * cos)

    return lr


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

@register_dataclass
@dataclass
class AdamState:
    mu: Any
    nu: Any
    count: jax.Array


def adamw_init(params) -> AdamState:
    z = jax.tree.map(
        lambda x: jnp.zeros_like(x, jnp.float32) if x is not None else None,
        params, is_leaf=lambda z: z is None)
    return AdamState(mu=z, nu=jax.tree.map(
        lambda x: None if x is None else jnp.zeros_like(x),
        z, is_leaf=lambda y: y is None), count=jnp.zeros((), jnp.int32))


def adamw_update(grads, state: AdamState, params, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, ascend: bool = False):
    """One AdamW step.  ``ascend=True`` flips the update (gradient
    ascent, used for the Lagrange multipliers)."""
    c = state.count + 1
    isnone = lambda z: z is None

    def new_mu(g, m):
        return None if g is None else b1 * m + (1 - b1) * g.astype(
            jnp.float32)

    def new_nu(g, v):
        return None if g is None else b2 * v + (1 - b2) * jnp.square(
            g.astype(jnp.float32))

    mu = jax.tree.map(new_mu, grads, state.mu, is_leaf=isnone)
    nu = jax.tree.map(new_nu, grads, state.nu, is_leaf=isnone)

    def upd(m, v, p):
        if m is None or p is None:
            return None
        mhat = m / (1 - b1 ** c)
        vhat = v / (1 - b2 ** c)
        step = lr * mhat / (jnp.sqrt(vhat) + eps)
        if ascend:  # gradient ascent; no decay on multipliers
            new_p = p.astype(jnp.float32) + step
        else:
            new_p = (p.astype(jnp.float32) - step
                     - lr * weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype)

    new_params = jax.tree.map(upd, mu, nu, params, is_leaf=isnone)
    return new_params, AdamState(mu=mu, nu=nu, count=c)
