"""Serving engine: prefill → route once → device-resident sparse decode.

Flow (paper §3.3 + DESIGN.md §Serving):
  1. ``prefill`` runs the model over the prompt with *hard* routing; the
     Layer Router fires exactly once per layer and the decision is
     returned to the host.
  2. ``repack_caches`` converts the full prefill KV into the per-layer
     decode caches the routing pattern dictates: FA layers keep the
     complete history, SA layers keep only the sink+local ring — the
     paper's KV-cache reduction, realized structurally.
  3. ``decode_many`` generates all requested tokens in ONE compiled
     call: a ``lax.scan`` over decode steps with on-device sampling,
     donated cache buffers (every append is an in-place
     ``dynamic_update_slice``), and tokens synced to host once at the
     end.  The compiled executable is keyed by the *cache geometry*
     (which full/ring buffer shapes exist), not by the fa/sa routing
     tuple — patterns sharing a geometry share an executable, and
     ``ServeEngine`` asserts the jit cache stays O(#geometries).

``sparse_decode=False`` reproduces the paper's non-shaded rows: routing
affects prefill only and decode keeps full KV everywhere.
"""
from __future__ import annotations

import contextlib
import warnings
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as MD
from repro.serve import kv_cache as KC


# ---------------------------------------------------------------------------
# Cache repacking
# ---------------------------------------------------------------------------

def _ring_src(seq_len: int, sink: int, local: int, ring: int) -> np.ndarray:
    """Per-ring-slot source position in the prefill KV (-1 = empty)."""
    src = np.full((ring,), -1, np.int64)
    ns = min(sink, seq_len, ring)
    src[:ns] = np.arange(ns)
    for p in range(max(sink, seq_len - local), seq_len):
        src[sink + (p - sink) % local] = p
    return src


def _gather_ring(k_full: jax.Array, src: np.ndarray, axis: int) -> jax.Array:
    idx = jnp.asarray(np.maximum(src, 0))
    g = jnp.take(k_full, idx, axis=axis)
    shape = [1] * g.ndim
    shape[axis] = len(src)
    mask = jnp.asarray(src >= 0).reshape(shape)
    return jnp.where(mask, g, 0)


def repack_caches(cfg: ModelConfig, prefill_caches, routing,
                  seq_len: int, max_len: int):
    """Prefill caches (stacked per period position) → decode cache list.

    routing[i] ∈ {"fa","sa",("duo",n),None}; seq_len = prompt length
    (incl. any modality prefix); max_len = decode cache capacity for FA
    layers.  Only "sa" changes the geometry (ring); duo layers keep the
    full cache (ragged per-head histories are unrepresentable — §2.3).
    Every row of the resulting caches starts at the same ``seq_len``;
    per-slot ``positions``/``length`` diverge once the caches join a
    continuous-batching slot pool (DESIGN.md §Scheduler).
    """
    flux = cfg.flux
    P = MD.period_len(cfg)
    out = []

    def _full_pad(layer: int) -> int:
        # ring layers truncate long prompts structurally; full-cache
        # layers cannot — seq_len > max_len would be a negative pad
        # surfacing as a cryptic XLA shape error, so refuse loudly.
        if seq_len > max_len:
            raise ValueError(
                f"repack_caches: prompt length seq_len={seq_len} exceeds "
                f"the decode cache capacity max_len={max_len} at full-"
                f"cache layer {layer}; raise the engine's max_len or "
                f"truncate the prompt")
        return max_len - seq_len

    def _positions(src: np.ndarray, batch: int) -> jax.Array:
        return jnp.broadcast_to(jnp.asarray(src, jnp.int32),
                                (batch, len(src)))

    def _length(batch: int) -> jax.Array:
        return jnp.full((batch,), seq_len, jnp.int32)

    for i, kind in enumerate(cfg.layer_kinds):
        per, pos = divmod(i, P)
        c = jax.tree.map(lambda a: a[per], prefill_caches[pos])
        if kind == "mamba":
            h, tail = c
            out.append(KC.MambaCache(h=h, conv_tail=tail))
            continue
        if cfg.use_mla:
            ckv, kr = c  # (B,S,R), (B,1,S,rope)
            B = ckv.shape[0]
            if kind == "attn" and routing[i] == "sa":
                ring, sink = KC.sa_ring(flux, max_len)
                src = _ring_src(seq_len, sink, ring - sink, ring)
                out.append(KC.RingLatentKV(
                    ckv=_gather_ring(ckv, src, 1),
                    kr=_gather_ring(kr, src, 2),
                    positions=_positions(src, B), length=_length(B)))
            else:
                pad = _full_pad(i)
                out.append(KC.LatentKV(
                    ckv=jnp.pad(ckv, ((0, 0), (0, pad), (0, 0))),
                    kr=jnp.pad(kr, ((0, 0), (0, 0), (0, pad), (0, 0))),
                    length=_length(B)))
            continue
        k, v = c  # (B,Hkv,S,D)
        B = k.shape[0]
        if kind == "local":
            ring = min(cfg.sliding_window, max_len)
            src = _ring_src(seq_len, 0, ring, ring)
            out.append(KC.RingKV(
                k=_gather_ring(k, src, 2), v=_gather_ring(v, src, 2),
                positions=_positions(src, B), length=_length(B)))
        elif kind == "attn" and routing[i] == "sa":
            ring, sink = KC.sa_ring(flux, max_len)
            src = _ring_src(seq_len, sink, ring - sink, ring)
            out.append(KC.RingKV(
                k=_gather_ring(k, src, 2), v=_gather_ring(v, src, 2),
                positions=_positions(src, B), length=_length(B)))
        else:
            pad = _full_pad(i)
            out.append(KC.FullKV(
                k=jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))),
                v=jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))),
                length=_length(B)))
    return out


# ---------------------------------------------------------------------------
# Cache accounting: KV payload vs. bookkeeping overhead
# ---------------------------------------------------------------------------

@dataclass
class KVStats:
    """Decode-cache footprint, split the way the paper counts it:
    ``payload_bytes`` is the KV (or SSM-state) tensors the routing
    decision actually shrinks; ``overhead_bytes`` is bookkeeping
    (``positions``/``length``) that exists for every geometry alike."""
    payload_bytes: int
    overhead_bytes: int

    @property
    def total_bytes(self) -> int:
        return self.payload_bytes + self.overhead_bytes


def kv_cache_stats(caches) -> KVStats:
    payload = overhead = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(caches)[0]:
        name = getattr(path[-1], "name", None) if path else None
        nbytes = leaf.size * leaf.dtype.itemsize
        if name in KC.OVERHEAD_FIELDS:
            overhead += nbytes
        else:
            payload += nbytes
    return KVStats(payload_bytes=payload, overhead_bytes=overhead)


def kv_cache_bytes(caches) -> int:
    """KV *payload* bytes only — the quantity the paper's KV-reduction
    claim is about.  Bookkeeping arrays (``positions``, ``length``) are
    reported separately via ``kv_cache_stats``."""
    return kv_cache_stats(caches).payload_bytes


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

def _arr_sig(a) -> Optional[Tuple]:
    """Traced-array structure (shape, dtype) that keys a jit entry."""
    return None if a is None else (tuple(a.shape), str(a.dtype))


def decode_executable_key(caches, pos, n_steps: int, greedy: bool,
                          duo_layers, enc_out, rng) -> Tuple:
    """The full static+structural signature of one ``decode_many``
    executable.  ``ServeEngine`` and ``ContinuousScheduler`` both record
    these so the executable-count guard can compare against the jit
    cache — the pos signature matters because a slot pool decodes with
    per-slot (B,) positions while ``generate`` uses a shared scalar."""
    return (KC.cache_geometry(caches), _arr_sig(jnp.asarray(pos)),
            n_steps, greedy, duo_layers, _arr_sig(enc_out), _arr_sig(rng))


@dataclass
class GenerationResult:
    tokens: np.ndarray            # (B, n_steps)
    routing: Tuple[Any, ...]      # per-layer decode pattern
    msr: float                    # SA fraction over routed layers
    kv_bytes: int                 # decode-cache footprint
    p_fa: Optional[np.ndarray] = None
    dispatches: int = 0           # compiled calls issued for this request


class ServeEngine:
    """Single-model serving with flux routing.

    ``routing_override``: force a per-layer pattern (baselines /
    ablations) instead of consulting the router; entries may be "fa",
    "sa", ("duo", n_fa_kv) or None.  ``generate`` also accepts a
    per-request override.

    Decode dispatch discipline: one ``decode_many`` scan per request
    (``dispatch_count`` tracks compiled calls), one executable per
    distinct (cache geometry, n_steps, sampling mode) — two routing
    patterns with the same geometry reuse one executable, and
    ``_check_executable_guard`` raises if a pattern-keyed recompile
    ever sneaks back in.
    """

    def __init__(self, params, cfg: ModelConfig, *, max_len: int = 4096,
                 sparse_decode: bool = True, routing_override=None,
                 decode_attn=None, decode_unroll: int = 4):
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self.sparse_decode = sparse_decode
        self.routing_override = routing_override
        self.decode_unroll = decode_unroll
        self._scheduler = None  # lazy ContinuousScheduler (submit/step)
        # optional decode-attention backend (e.g. the Pallas flash-decode
        # kernel via kernels.decode_attention.make_kernel_decode_attn);
        # installed at trace time, baked into the compiled scan.
        self.decode_attn = decode_attn
        self.dispatch_count = 0           # compiled calls, engine lifetime
        self._decode_keys: set = set()    # expected decode executables
        self._prefill = jax.jit(partial(MD.prefill, cfg=cfg),
                                static_argnames=("routing_ctx",))
        # repack is a long chain of tiny gathers/pads — eager dispatch
        # costs more than the math at serving rates, so compile it per
        # (pattern, seq_len).  Admission-heavy continuous batching runs
        # one of these per request.
        self._repack = jax.jit(
            partial(repack_caches, cfg),
            static_argnames=("routing", "seq_len", "max_len"))
        self._decode_many = jax.jit(
            partial(MD.decode_many, cfg=cfg),
            static_argnames=("n_steps", "greedy", "duo_layers", "unroll"),
            donate_argnames=("caches",))
        self._encode = (jax.jit(partial(MD.encode, cfg=cfg))
                        if cfg.num_encoder_layers else None)

    # -- routing pattern ---------------------------------------------------
    def _pattern(self, decisions: Optional[np.ndarray],
                 override=None) -> Tuple[Any, ...]:
        cfg = self.cfg
        override = override if override is not None else \
            self.routing_override
        routed = list(cfg.routable_layers())
        pattern: List[Any] = [None] * cfg.num_layers
        for i, kind in enumerate(cfg.layer_kinds):
            if kind != "attn":
                continue
            if not cfg.flux.enabled:
                pattern[i] = "fa"
            elif override is not None:
                pattern[i] = override[i]
            elif decisions is None or not self.sparse_decode:
                pattern[i] = "fa"
            else:
                j = routed.index(i)
                pattern[i] = "fa" if int(decisions[j]) else "sa"
        return tuple(pattern)

    # -- jit-cache bookkeeping ---------------------------------------------
    def decode_cache_size(self) -> int:
        """Number of compiled decode executables held by this engine."""
        return self._decode_many._cache_size()

    def _check_executable_guard(self) -> None:
        """The decode jit cache must stay O(#geometries) — one entry per
        (cache geometry, n_steps, greedy) actually served — never
        O(2^routable_layers) pattern-keyed entries."""
        compiled, expected = self.decode_cache_size(), len(self._decode_keys)
        if compiled > expected:
            raise RuntimeError(
                f"decode executable explosion: {compiled} compiled for "
                f"{expected} (geometry, n_steps, sampling) keys — a "
                f"routing-pattern-static argument has leaked into the "
                f"decode jit signature")

    # -- API -----------------------------------------------------------------
    def prefill_route_repack(self, tokens: jax.Array, override=None, *,
                             prefix_embeddings=None, encoder_frames=None):
        """The shared admission chain: prefill (router fires once) →
        per-request routing pattern → decode caches of the routed
        geometry.  Both ``generate`` and the continuous-batching
        scheduler go through this, so routing precedence can never
        diverge between the two frontends.
        Returns (pf, pattern, caches, seq_len)."""
        cfg = self.cfg
        override = (override if override is not None
                    else self.routing_override)
        routing_ctx = "hard" if (cfg.flux.enabled
                                 and override is None
                                 and cfg.routable_layers()) else "fa_only"
        pf = self._prefill(params=self.params, tokens=tokens,
                           routing_ctx=routing_ctx,
                           prefix_embeddings=prefix_embeddings,
                           encoder_frames=encoder_frames)
        decisions = (np.asarray(pf.routing)
                     if pf.routing is not None else None)
        pattern = self._pattern(decisions, override)
        seq_len = tokens.shape[1] + (prefix_embeddings.shape[1]
                                     if prefix_embeddings is not None else 0)
        caches = self._repack(pf.caches, routing=pattern,
                              seq_len=seq_len, max_len=self.max_len)
        return pf, pattern, caches, seq_len

    def generate(self, tokens: np.ndarray, n_steps: int, *,
                 prefix_embeddings=None, encoder_frames=None,
                 greedy: bool = True, rng=None,
                 routing_override=None) -> GenerationResult:
        cfg = self.cfg
        tokens = jnp.asarray(tokens)
        dispatches = 0
        enc_out = None
        if self._encode is not None:
            enc_out = self._encode(params=self.params, frames=encoder_frames)
            dispatches += 1
        pf, pattern, caches, seq_len = self.prefill_route_repack(
            tokens, routing_override, prefix_embeddings=prefix_embeddings,
            encoder_frames=encoder_frames)
        dispatches += 2  # prefill + the jitted repack
        kv_bytes = kv_cache_bytes(caches)

        greedy = bool(greedy or rng is None)
        rng = rng if rng is not None else jax.random.key(0)
        fa_heads, duo_layers = MD.routing_head_split(cfg, pattern)
        pos = jnp.int32(seq_len)
        self._decode_keys.add(decode_executable_key(
            caches, pos, n_steps, greedy, duo_layers, enc_out, rng))
        attn_ctx = (MD.use_decode_attn(self.decode_attn)
                    if self.decode_attn is not None
                    else contextlib.nullcontext())
        with warnings.catch_warnings(), attn_ctx:
            # donation is a no-op on backends without buffer aliasing
            # (CPU tests) — harmless, silence the per-call warning
            warnings.filterwarnings("ignore", message=".*[Dd]onat.*")
            toks, _, _ = self._decode_many(
                params=self.params, logits=pf.logits, caches=caches,
                pos=pos, rng=rng, n_steps=n_steps,
                greedy=greedy, enc_out=enc_out, fa_heads=fa_heads,
                duo_layers=duo_layers, unroll=self.decode_unroll)
        dispatches += 1
        self.dispatch_count += dispatches
        self._check_executable_guard()
        routed = [p for p in pattern if p is not None]
        msr_val = (sum(p == "sa" for p in routed) / len(routed)
                   if routed else float("nan"))
        return GenerationResult(
            tokens=np.asarray(toks), routing=pattern,
            msr=msr_val, kv_bytes=kv_bytes,
            p_fa=None if pf.p_fa is None else np.asarray(pf.p_fa),
            dispatches=dispatches)

    # -- continuous-batching (streaming) frontend ---------------------------
    def scheduler(self, **kw):
        """The engine's ``ContinuousScheduler`` (created on first use;
        kwargs configure it then — slots_per_bucket, chunk, clock)."""
        if self._scheduler is None:
            from repro.serve.scheduler import ContinuousScheduler
            self._scheduler = ContinuousScheduler(self, **kw)
        elif kw:
            raise ValueError(
                "scheduler already created; configure it on first call")
        return self._scheduler

    def submit(self, req: "Request") -> int:
        """Queue a request for continuous batching; returns its rid."""
        return self.scheduler().submit(req)

    def step(self):
        """One scheduling tick: admit, decode one chunk per geometry
        bucket, retire.  Returns the requests finished this tick."""
        return self.scheduler().tick()

    def drain(self):
        """Tick until every submitted request finished; returns
        {rid: FinishedRequest} with TTFT/throughput metrics."""
        return self.scheduler().drain()


# ---------------------------------------------------------------------------
# Request frontends: batch-synchronous and continuous (streaming)
# ---------------------------------------------------------------------------

@dataclass
class Request:
    rid: int
    tokens: np.ndarray  # (S,)
    n_steps: int        # max new tokens
    eos_id: Optional[int] = None   # stop early on this token
    # higher preempts lower when continuous-batching pools fill;
    # meaningless under serve_batch (no slot contention there)
    priority: int = 0
    routing_override: Optional[Tuple[Any, ...]] = None


def _trim_eos(tokens: np.ndarray, eos_id: Optional[int]) -> np.ndarray:
    """Cut a generated stream after the first EOS (inclusive)."""
    if eos_id is None:
        return tokens
    hits = np.flatnonzero(tokens == eos_id)
    return tokens[:hits[0] + 1] if hits.size else tokens


def serve_batch(engine: ServeEngine, requests: Sequence[Request]
                ) -> Dict[int, np.ndarray]:
    """Bucket requests by (length, n_steps, routing_override) and serve
    each bucket batched.  ``eos_id`` trims each stream host-side (the
    fused scan still decodes all n_steps — early exit is what the
    continuous frontend is for), so both frontends return the same
    tokens for the same Request.

    Layer routing is per-bucket (batch-consensus inside the model); the
    paper evaluates per-request routing at B=1 — buckets of size 1
    reproduce that exactly.
    """
    buckets: Dict[Tuple, List[Request]] = {}
    for r in requests:
        buckets.setdefault((len(r.tokens), r.n_steps, r.routing_override),
                           []).append(r)
    results: Dict[int, np.ndarray] = {}
    for (_, n_steps, override), rs in buckets.items():
        toks = np.stack([r.tokens for r in rs])
        gen = engine.generate(toks, n_steps, routing_override=override)
        for i, r in enumerate(rs):
            results[r.rid] = _trim_eos(gen.tokens[i], r.eos_id)
    return results
