"""Serving engine: prefill → route once → sparse decode (paper §3.3).

Flow:
  1. ``prefill`` runs the model over the prompt with *hard* routing; the
     Layer Router fires exactly once per layer and the decision is
     returned to the host.
  2. ``repack_caches`` converts the full prefill KV into the per-layer
     decode caches the routing pattern dictates: FA layers keep the
     complete history, SA layers keep only the sink+local ring — the
     paper's KV-cache reduction, realized structurally.
  3. ``decode_step`` jit-specializes on the routing pattern (a static
     tuple); repeated patterns hit the jit cache.  Requests are bucketed
     by (length, pattern).

``sparse_decode=False`` reproduces the paper's non-shaded rows: routing
affects prefill only and decode keeps full KV everywhere.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as MD
from repro.serve import kv_cache as KC


# ---------------------------------------------------------------------------
# Cache repacking
# ---------------------------------------------------------------------------

def _ring_src(seq_len: int, sink: int, local: int, ring: int) -> np.ndarray:
    """Per-ring-slot source position in the prefill KV (-1 = empty)."""
    src = np.full((ring,), -1, np.int64)
    ns = min(sink, seq_len)
    src[:ns] = np.arange(ns)
    for p in range(max(sink, seq_len - local), seq_len):
        src[sink + (p - sink) % local] = p
    return src


def _gather_ring(k_full: jax.Array, src: np.ndarray, axis: int) -> jax.Array:
    idx = jnp.asarray(np.maximum(src, 0))
    g = jnp.take(k_full, idx, axis=axis)
    shape = [1] * g.ndim
    shape[axis] = len(src)
    mask = jnp.asarray(src >= 0).reshape(shape)
    return jnp.where(mask, g, 0)


def repack_caches(cfg: ModelConfig, prefill_caches, routing: Tuple[str, ...],
                  seq_len: int, max_len: int):
    """Prefill caches (stacked per period position) → decode cache list.

    routing[i] ∈ {"fa","sa",None}; seq_len = prompt length (incl. any
    modality prefix); max_len = decode cache capacity for FA layers.
    """
    flux = cfg.flux
    P = MD.period_len(cfg)
    # map layer → (period, cache slot within period)
    cache_positions = [pos for pos in range(P)]  # every kind yields a cache
    out = []
    for i, kind in enumerate(cfg.layer_kinds):
        per, pos = divmod(i, P)
        c = jax.tree.map(lambda a: a[per], prefill_caches[pos])
        if kind == "mamba":
            h, tail = c
            out.append(KC.MambaCache(h=h, conv_tail=tail))
            continue
        if cfg.use_mla:
            ckv, kr = c  # (B,S,R), (B,1,S,rope)
            B = ckv.shape[0]
            if kind == "attn" and routing[i] == "sa":
                ring = min(flux.sink + flux.local, max_len)
                src = _ring_src(seq_len, flux.sink, ring - flux.sink, ring)
                out.append(KC.RingLatentKV(
                    ckv=_gather_ring(ckv, src, 1),
                    kr=_gather_ring(kr, src, 2),
                    positions=jnp.asarray(src, jnp.int32),
                    length=jnp.int32(seq_len)))
            else:
                pad = max_len - seq_len
                out.append(KC.LatentKV(
                    ckv=jnp.pad(ckv, ((0, 0), (0, pad), (0, 0))),
                    kr=jnp.pad(kr, ((0, 0), (0, 0), (0, pad), (0, 0))),
                    length=jnp.int32(seq_len)))
            continue
        k, v = c  # (B,Hkv,S,D)
        if kind == "local":
            ring = min(cfg.sliding_window, max_len)
            src = _ring_src(seq_len, 0, ring, ring)
            out.append(KC.RingKV(
                k=_gather_ring(k, src, 2), v=_gather_ring(v, src, 2),
                positions=jnp.asarray(src, jnp.int32),
                length=jnp.int32(seq_len)))
        elif kind == "attn" and routing[i] == "sa":
            ring = min(flux.sink + flux.local, max_len)
            src = _ring_src(seq_len, flux.sink, ring - flux.sink, ring)
            out.append(KC.RingKV(
                k=_gather_ring(k, src, 2), v=_gather_ring(v, src, 2),
                positions=jnp.asarray(src, jnp.int32),
                length=jnp.int32(seq_len)))
        else:
            pad = max_len - seq_len
            out.append(KC.FullKV(
                k=jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))),
                v=jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))),
                length=jnp.int32(seq_len)))
    return out


def kv_cache_bytes(caches) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(caches))


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

@dataclass
class GenerationResult:
    tokens: np.ndarray            # (B, n_steps)
    routing: Tuple[str, ...]      # per-layer decode pattern
    msr: float                    # SA fraction over routed layers
    kv_bytes: int                 # decode-cache footprint
    p_fa: Optional[np.ndarray] = None


class ServeEngine:
    """Single-model serving with flux routing.

    ``routing_override``: force a per-layer pattern (baselines/ablations)
    instead of consulting the router.
    """

    def __init__(self, params, cfg: ModelConfig, *, max_len: int = 4096,
                 sparse_decode: bool = True, routing_override=None):
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self.sparse_decode = sparse_decode
        self.routing_override = routing_override
        self._prefill = jax.jit(partial(MD.prefill, cfg=cfg),
                                static_argnames=("routing_ctx",))
        self._decode = jax.jit(partial(MD.decode_step, cfg=cfg),
                               static_argnames=("routing",))
        self._encode = (jax.jit(partial(MD.encode, cfg=cfg))
                        if cfg.num_encoder_layers else None)

    # -- routing pattern ---------------------------------------------------
    def _pattern(self, decisions: Optional[np.ndarray]) -> Tuple[str, ...]:
        cfg = self.cfg
        routed = list(cfg.routable_layers())
        pattern: List[Optional[str]] = [None] * cfg.num_layers
        for i, kind in enumerate(cfg.layer_kinds):
            if kind != "attn":
                continue
            if not cfg.flux.enabled:
                pattern[i] = "fa"
            elif self.routing_override is not None:
                pattern[i] = self.routing_override[i]
            elif decisions is None or not self.sparse_decode:
                pattern[i] = "fa"
            else:
                j = routed.index(i)
                pattern[i] = "fa" if int(decisions[j]) else "sa"
        return tuple(pattern)

    # -- API -----------------------------------------------------------------
    def generate(self, tokens: np.ndarray, n_steps: int, *,
                 prefix_embeddings=None, encoder_frames=None,
                 greedy: bool = True, rng=None) -> GenerationResult:
        cfg = self.cfg
        tokens = jnp.asarray(tokens)
        B, S = tokens.shape
        enc_out = (self._encode(params=self.params, frames=encoder_frames)
                   if self._encode is not None else None)
        routing_ctx = "hard" if (cfg.flux.enabled
                                 and self.routing_override is None
                                 and cfg.routable_layers()) else "fa_only"
        pf = self._prefill(params=self.params, tokens=tokens,
                           routing_ctx=routing_ctx,
                           prefix_embeddings=prefix_embeddings,
                           encoder_frames=encoder_frames)
        decisions = (np.asarray(pf.routing)
                     if pf.routing is not None else None)
        pattern = self._pattern(decisions)
        seq_len = S + (prefix_embeddings.shape[1]
                       if prefix_embeddings is not None else 0)
        caches = repack_caches(cfg, pf.caches, pattern, seq_len,
                               self.max_len)
        kv_bytes = kv_cache_bytes(caches)

        logits = pf.logits
        out_tokens = []
        pos = seq_len
        for step in range(n_steps):
            if greedy or rng is None:
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                rng, k = jax.random.split(rng)
                nxt = jax.random.categorical(k, logits).astype(jnp.int32)
            out_tokens.append(np.asarray(nxt))
            logits, caches = self._decode(
                params=self.params, token=nxt[:, None], caches=caches,
                routing=pattern, pos=jnp.int32(pos), enc_out=enc_out)
            pos += 1
        routed = [p for p in pattern if p is not None]
        msr_val = (sum(p == "sa" for p in routed) / len(routed)
                   if routed else float("nan"))
        return GenerationResult(
            tokens=np.stack(out_tokens, axis=1), routing=pattern,
            msr=msr_val, kv_bytes=kv_bytes,
            p_fa=None if pf.p_fa is None else np.asarray(pf.p_fa))


# ---------------------------------------------------------------------------
# Batched request frontend
# ---------------------------------------------------------------------------

@dataclass
class Request:
    rid: int
    tokens: np.ndarray  # (S,)
    n_steps: int


def serve_batch(engine: ServeEngine, requests: Sequence[Request]
                ) -> Dict[int, np.ndarray]:
    """Bucket requests by (length, n_steps) and serve each bucket batched.

    Layer routing is per-bucket (batch-consensus inside the model); the
    paper evaluates per-request routing at B=1 — buckets of size 1
    reproduce that exactly.
    """
    buckets: Dict[Tuple[int, int], List[Request]] = {}
    for r in requests:
        buckets.setdefault((len(r.tokens), r.n_steps), []).append(r)
    results: Dict[int, np.ndarray] = {}
    for (_, n_steps), rs in buckets.items():
        toks = np.stack([r.tokens for r in rs])
        gen = engine.generate(toks, n_steps)
        for i, r in enumerate(rs):
            results[r.rid] = gen.tokens[i]
    return results
