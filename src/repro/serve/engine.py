"""Serving engine: route on the first chunk → stream the rest into
decode-geometry caches → device-resident sparse decode.

Admission (paper §3.3 + DESIGN.md §Prefill pipeline) is a chunked,
cache-resident pipeline:
  1. The prompt is decomposed into bucketed chunks (``chunk_plan``).
     The first chunk runs as a small monolithic prefill with
     prefix-pooled hard routing — the Layer Router fires exactly once
     per layer and the per-layer FA/SA pattern is frozen (§3.3).
  2. Decode-geometry caches are allocated from the pattern and seeded
     with the first chunk's KV (``seed_caches``); remaining chunks
     stream through ``MD.prefill_chunk`` writing *directly* into them —
     ``full_insert`` at FA layers, ``ring_insert`` at SA layers.  Peak
     live KV at SA layers is bounded by the ring, not the prompt, and
     no full-sequence KV is ever materialized or repacked.
  3. ``decode_many`` generates all requested tokens in ONE compiled
     call: a ``lax.scan`` over decode steps with on-device sampling and
     donated cache buffers.  Every compiled artifact on the serving
     path — seed, stream chunk, decode — is keyed by the *cache
     geometry* (× chunk bucket for prefill), never by the fa/sa routing
     tuple, and ``ServeEngine`` asserts those jit caches stay bounded.

``prefill_route_repack`` (full prefill → host-planned repack) remains
as the fallback for cases the chunked path excludes — see its
docstring.  ``sparse_decode=False`` reproduces the paper's non-shaded
rows: routing affects prefill only and decode keeps full KV everywhere.
"""
from __future__ import annotations

import contextlib
import time
import warnings
from collections import Counter
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
# module (not name) import: core.router itself imports models.layers,
# whose package chain loads repro.serve — a name import here would trip
# that cycle at interpreter start
from repro.core import router as RT
from repro.distributed import pool_sharding as PSH
from repro.launch import hlo_costs as HL
from repro.launch import shardings as SHD
from repro.models import model as MD
from repro.serve import kv_cache as KC
from repro.serve import prefix_cache as PXC
from repro.serve import slo as SLO
from repro.serve import telemetry as TM
from repro.serve import tracing as TR

# Closed decline vocabulary shared by every decode-attention adapter
# (kernels.decode_attention, distributed.decode): the engine
# pre-registers one counter label per reason, so a new reason MUST be
# added here or its declines silently never export.
DECODE_KERNEL_DECLINE_REASONS = ("min_len", "mask_rank")


# ---------------------------------------------------------------------------
# Chunk planning (host-side, static)
# ---------------------------------------------------------------------------

def chunk_plan(seq_len: int, chunk: int) -> List[Tuple[int, int]]:
    """Decompose a prompt into bucketed chunks: [(start, size), ...].

    Sizes are drawn from the static ladder {chunk} ∪ {2^k : 2^k < chunk},
    largest first, covering ``seq_len`` *exactly* — padding is never an
    option because padded tokens would be ring-inserted (corrupting
    ``positions``) and would advance Mamba state with garbage.  The
    ladder bounds compiled chunk executables at O(#geometries ×
    #buckets) with #buckets ≤ log2(chunk)+2, the engine's guard budget.
    """
    if seq_len <= 0:
        raise ValueError(f"chunk_plan: seq_len={seq_len} must be positive")
    if chunk <= 0:
        raise ValueError(f"chunk_plan: chunk={chunk} must be positive")
    plan: List[Tuple[int, int]] = []
    start = 0
    while seq_len - start >= chunk:
        plan.append((start, chunk))
        start += chunk
    rem = seq_len - start
    if rem:
        b = 1 << (rem.bit_length() - 1)  # largest power of two <= rem
        while rem:
            if b <= rem:
                plan.append((start, b))
                start += b
                rem -= b
            b >>= 1
    return plan


# ---------------------------------------------------------------------------
# Chunk-0 seeding: first-chunk prefill KV → fresh decode-geometry caches
# ---------------------------------------------------------------------------

def seed_caches(cfg: ModelConfig, prefill_caches, pattern,
                batch: int, max_len: int):
    """Build decode-geometry caches for ``pattern`` and insert a
    routing-chunk's per-layer KV (stacked per period position, as
    ``MD.prefill`` returns it) at position 0 — one compiled call,
    entirely device-side: the chunked replacement for the host-planned
    gathers of ``repack_caches``.  ``pattern`` is static but fa/sa
    patterns map 1:1 onto cache geometries, so the jit cache still
    holds one entry per (geometry, first-chunk bucket)."""
    caches = KC.init_decode_caches(cfg, pattern, batch, max_len)
    flux = cfg.flux
    P = MD.period_len(cfg)
    start = jnp.int32(0)
    out = []
    for i, kind in enumerate(cfg.layer_kinds):
        per, pos = divmod(i, P)
        c = jax.tree.map(lambda a: a[per], prefill_caches[pos])
        dec = caches[i]
        if kind == "mamba":
            h, tail = c
            out.append(KC.MambaCache(h=h, conv_tail=tail))
            continue
        if cfg.use_mla:
            ckv, kr = c
            if isinstance(dec, KC.RingLatentKV):
                ring = dec.ckv.shape[1]
                sink = 0 if kind == "local" else flux.sink
                out.append(KC.ring_latent_insert_chunk(
                    dec, ckv, kr, start, sink, ring - sink))
            else:
                out.append(KC.latent_insert_chunk(dec, ckv, kr, start))
            continue
        k, v = c
        if isinstance(dec, KC.RingKV):
            ring = dec.k.shape[2]
            sink = 0 if kind == "local" else flux.sink
            out.append(KC.ring_insert_chunk(dec, k, v, start, sink,
                                            ring - sink))
        else:
            out.append(KC.full_insert_chunk(dec, k, v, start))
    return out


# ---------------------------------------------------------------------------
# Cache repacking (monolithic fallback path)
# ---------------------------------------------------------------------------

def _ring_src(seq_len: int, sink: int, local: int, ring: int) -> np.ndarray:
    """Per-ring-slot source position in the prefill KV (-1 = empty)."""
    src = np.full((ring,), -1, np.int64)
    ns = min(sink, seq_len, ring)
    src[:ns] = np.arange(ns)
    for p in range(max(sink, seq_len - local), seq_len):
        src[sink + (p - sink) % local] = p
    return src


def _gather_ring(k_full: jax.Array, src: np.ndarray, axis: int) -> jax.Array:
    idx = jnp.asarray(np.maximum(src, 0))
    g = jnp.take(k_full, idx, axis=axis)
    shape = [1] * g.ndim
    shape[axis] = len(src)
    mask = jnp.asarray(src >= 0).reshape(shape)
    return jnp.where(mask, g, 0)


def repack_caches(cfg: ModelConfig, prefill_caches, routing,
                  seq_len: int, max_len: int):
    """Prefill caches (stacked per period position) → decode cache list.

    FALLBACK PATH: the chunked admission (``seed_caches`` + the
    device-side chunk inserts in ``kv_cache``) replaced this in the
    serving hot path — the host-planned ``_ring_src`` gather plans here
    survive only for admissions ``chunked_eligible`` excludes.

    routing[i] ∈ {"fa","sa",("duo",n),None}; seq_len = prompt length
    (incl. any modality prefix); max_len = decode cache capacity for FA
    layers.  Only "sa" changes the geometry (ring); duo layers keep the
    full cache (ragged per-head histories are unrepresentable — §2.3).
    Every row of the resulting caches starts at the same ``seq_len``;
    per-slot ``positions``/``length`` diverge once the caches join a
    continuous-batching slot pool (DESIGN.md §Scheduler).
    """
    flux = cfg.flux
    P = MD.period_len(cfg)
    out = []

    def _full_pad(layer: int) -> int:
        # ring layers truncate long prompts structurally; full-cache
        # layers cannot — seq_len > max_len would be a negative pad
        # surfacing as a cryptic XLA shape error, so refuse loudly.
        if seq_len > max_len:
            raise ValueError(
                f"repack_caches: prompt length seq_len={seq_len} exceeds "
                f"the decode cache capacity max_len={max_len} at full-"
                f"cache layer {layer}; raise the engine's max_len or "
                f"truncate the prompt")
        return max_len - seq_len

    def _positions(src: np.ndarray, batch: int) -> jax.Array:
        return jnp.broadcast_to(jnp.asarray(src, jnp.int32),
                                (batch, len(src)))

    def _length(batch: int) -> jax.Array:
        return jnp.full((batch,), seq_len, jnp.int32)

    for i, kind in enumerate(cfg.layer_kinds):
        per, pos = divmod(i, P)
        c = jax.tree.map(lambda a: a[per], prefill_caches[pos])
        if kind == "mamba":
            h, tail = c
            out.append(KC.MambaCache(h=h, conv_tail=tail))
            continue
        if cfg.use_mla:
            ckv, kr = c  # (B,S,R), (B,1,S,rope)
            B = ckv.shape[0]
            if kind == "attn" and routing[i] == "sa":
                ring, sink = KC.sa_ring(flux, max_len)
                src = _ring_src(seq_len, sink, ring - sink, ring)
                out.append(KC.RingLatentKV(
                    ckv=_gather_ring(ckv, src, 1),
                    kr=_gather_ring(kr, src, 2),
                    positions=_positions(src, B), length=_length(B)))
            else:
                pad = _full_pad(i)
                out.append(KC.LatentKV(
                    ckv=jnp.pad(ckv, ((0, 0), (0, pad), (0, 0))),
                    kr=jnp.pad(kr, ((0, 0), (0, 0), (0, pad), (0, 0))),
                    length=_length(B)))
            continue
        k, v = c  # (B,Hkv,S,D)
        B = k.shape[0]
        if kind == "local":
            ring = min(cfg.sliding_window, max_len)
            src = _ring_src(seq_len, 0, ring, ring)
            out.append(KC.RingKV(
                k=_gather_ring(k, src, 2), v=_gather_ring(v, src, 2),
                positions=_positions(src, B), length=_length(B)))
        elif kind == "attn" and routing[i] == "sa":
            ring, sink = KC.sa_ring(flux, max_len)
            src = _ring_src(seq_len, sink, ring - sink, ring)
            out.append(KC.RingKV(
                k=_gather_ring(k, src, 2), v=_gather_ring(v, src, 2),
                positions=_positions(src, B), length=_length(B)))
        else:
            pad = _full_pad(i)
            out.append(KC.FullKV(
                k=jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))),
                v=jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))),
                length=_length(B)))
    return out


# ---------------------------------------------------------------------------
# Cache accounting: KV payload vs. bookkeeping overhead
# ---------------------------------------------------------------------------

@dataclass
class KVStats:
    """Decode-cache footprint, split the way the paper counts it:
    ``payload_bytes`` is the KV (or SSM-state) tensors the routing
    decision actually shrinks; ``overhead_bytes`` is bookkeeping
    (``positions``/``length``) that exists for every geometry alike.
    ``prefix_device_bytes``/``prefix_host_bytes`` report the
    shared-prefix snapshot store's occupancy per tier alongside —
    the store holds whole boundary states, so its bytes are neither
    payload nor overhead of any live request.

    ``payload_shard_bytes``/``overhead_shard_bytes`` are the bytes one
    device actually holds: for mesh-sharded pools the head-sharded k/v
    leaves divide by the "model" axis while replicated leaves count in
    full, so shard < global; on a single device (or a fully replicated
    tree) they equal the global figures.  The memory ledger reconciles
    against the *global* figures — exact under any mesh by
    construction (DESIGN.md §Distributed serving)."""
    payload_bytes: int
    overhead_bytes: int
    prefix_device_bytes: int = 0
    prefix_host_bytes: int = 0
    payload_shard_bytes: int = 0
    overhead_shard_bytes: int = 0

    @property
    def total_bytes(self) -> int:
        return self.payload_bytes + self.overhead_bytes


def _leaf_bytes(leaf) -> Tuple[int, int]:
    """(global, per-shard) bytes of one cache leaf.  The per-shard
    figure reads ``sharding.shard_shape`` when the leaf carries one
    (committed mesh arrays); host arrays and abstract specs fall back
    to global = shard."""
    nbytes = leaf.size * leaf.dtype.itemsize
    sharding = getattr(leaf, "sharding", None)
    if sharding is None or not hasattr(sharding, "shard_shape"):
        return nbytes, nbytes
    shard = int(np.prod(sharding.shard_shape(tuple(leaf.shape)),
                        dtype=np.int64)) if leaf.ndim else 1
    return nbytes, shard * leaf.dtype.itemsize


def kv_cache_stats(caches, prefix_store=None) -> KVStats:
    payload = overhead = payload_shard = overhead_shard = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(caches)[0]:
        name = getattr(path[-1], "name", None) if path else None
        nbytes, shard_bytes = _leaf_bytes(leaf)
        if name in KC.OVERHEAD_FIELDS:
            overhead += nbytes
            overhead_shard += shard_bytes
        else:
            payload += nbytes
            payload_shard += shard_bytes
    pd = ph = 0
    if prefix_store is not None:
        pd = prefix_store.device_bytes
        ph = prefix_store.host_bytes
    return KVStats(payload_bytes=payload, overhead_bytes=overhead,
                   prefix_device_bytes=pd, prefix_host_bytes=ph,
                   payload_shard_bytes=payload_shard,
                   overhead_shard_bytes=overhead_shard)


def kv_cache_bytes(caches) -> int:
    """KV *payload* bytes only — the quantity the paper's KV-reduction
    claim is about.  Bookkeeping arrays (``positions``, ``length``) are
    reported separately via ``kv_cache_stats``."""
    return kv_cache_stats(caches).payload_bytes


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

def _arr_sig(a) -> Optional[Tuple]:
    """Traced-array structure (shape, dtype) that keys a jit entry."""
    return None if a is None else (tuple(a.shape), str(a.dtype))


def decode_executable_key(caches, pos, n_steps: int, greedy: bool,
                          duo_layers, enc_out, rng,
                          mesh_sig: Optional[Tuple] = None) -> Tuple:
    """The full static+structural signature of one ``decode_many``
    executable.  ``ServeEngine`` and ``ContinuousScheduler`` both record
    these so the executable-count guard can compare against the jit
    cache — the pos signature matters because a slot pool decodes with
    per-slot (B,) positions while ``generate`` uses a shared scalar.
    ``mesh_sig`` (``pool_sharding.mesh_signature``) distinguishes the
    mesh-committed variants: sharding-committed inputs key separate jit
    entries, so the guard counts per-(geometry, mesh), never letting a
    mesh hide a pattern-keyed recompile."""
    return (KC.cache_geometry(caches), _arr_sig(jnp.asarray(pos)),
            n_steps, greedy, duo_layers, _arr_sig(enc_out), _arr_sig(rng),
            mesh_sig)


@dataclass
class ChunkedPrefill:
    """An in-flight route-then-stream admission (DESIGN.md §Prefill
    pipeline).

    ``step()`` processes exactly one chunk, so the continuous scheduler
    can interleave prefill chunks with decode ticks (Sarathi-style
    mixed ticks).  Step 0 is the *routing chunk*: a monolithic prefill
    over the first bucket (the Layer Router fires once per layer,
    prefix-pooled), then decode-geometry caches are allocated from the
    frozen pattern and seeded with the chunk's KV.  Every further step
    streams one bucketed chunk through ``MD.prefill_chunk`` directly
    into those caches.  After ``done``, the results live in
    ``pattern`` / ``caches`` / ``logits`` / ``p_fa``.

    Shared-prefix reuse (DESIGN.md §Prefix cache): when the engine has
    a prefix store and ``reuse`` holds, the job starts from the deepest
    matching chunk-boundary snapshot (``prefix_hit_tokens`` covered
    tokens skip straight past their chunks — no prefill work is issued
    for them) and publishes a new snapshot at every full-chunk boundary
    it streams, so the store warms as a side effect of serving.
    """
    engine: "ServeEngine"
    tokens: jax.Array                      # (B, S)
    override: Optional[Tuple[Any, ...]]
    plan: List[Tuple[int, int]]
    idx: int = 0
    dispatches: int = 0                    # compiled calls issued so far
    pattern: Optional[Tuple[Any, ...]] = None
    caches: Any = None
    logits: Optional[jax.Array] = None
    p_fa: Optional[np.ndarray] = None
    reuse: bool = True                     # participate in the prefix store
    # load-adaptive sparsity rung at admission time (serve/slo.py):
    # frozen when the job starts so a mid-prefill dial change cannot
    # split one request across two routing regimes
    sa_level: int = 0
    prefix_hit_tokens: int = 0             # prompt tokens seeded from a hit
    chunks_streamed: int = 0               # chunks actually computed
    published: int = 0                     # boundary snapshots published
    _geom: Optional[Tuple] = None

    @property
    def seq_len(self) -> int:
        return self.tokens.shape[1]

    @property
    def done(self) -> bool:
        return self.idx >= len(self.plan)

    @property
    def n_chunks(self) -> int:
        return len(self.plan)

    def step(self) -> None:
        """Process the next chunk (no-op when done)."""
        if self.done:
            return
        eng = self.engine
        start, size = self.plan[self.idx]
        chunk = self.tokens[:, start:start + size]
        if self.idx == 0:
            self._route_chunk(chunk)
        else:
            eng._stream_keys.add((self._geom, size))
            with warnings.catch_warnings():
                warnings.filterwarnings("ignore", message=".*[Dd]onat.*")
                self.logits, self.caches = eng._stream_chunk(
                    params=eng.params, tokens=chunk, caches=self.caches,
                    start=jnp.int32(start))
            self.caches, self.logits = eng._commit_state(
                self.caches, self.logits)
            self.dispatches += 1
        self.chunks_streamed += 1
        self.idx += 1
        eng._maybe_publish(self, start, size)

    def _route_chunk(self, chunk: jax.Array) -> None:
        eng, cfg = self.engine, self.engine.cfg
        routing_ctx, fixed = eng._routing_ctx(self.override)
        # sparsity dial: bias the hard-routing threshold toward SA at
        # this job's frozen rung.  Traced (not static), so every rung
        # shares one prefill executable; level 0 passes None and stays
        # bit-identical to the dial-free path.
        thr = (jnp.float32(eng.fa_threshold(self.sa_level))
               if self.sa_level > 0
               and routing_ctx in ("hard", "hard_prefix") else None)
        pf = eng._prefill(params=eng.params, tokens=chunk,
                          routing_ctx=routing_ctx, fixed_pattern=fixed,
                          prefix_embeddings=None, encoder_frames=None,
                          fa_threshold=thr)
        decisions = (np.asarray(pf.routing)
                     if pf.routing is not None else None)
        self.pattern = eng._pattern(decisions, self.override)
        self.p_fa = None if pf.p_fa is None else np.asarray(pf.p_fa)
        eng._record_routing(self.pattern, self.p_fa, self.sa_level)
        # geometry from abstract shapes only — the real buffers are
        # built inside the seed jit (no eager per-admission allocs)
        spec = jax.eval_shape(lambda: KC.init_decode_caches(
            cfg, self.pattern, chunk.shape[0], eng.max_len))
        self._geom = KC.cache_geometry(spec)
        eng._seed_keys.add((self._geom, chunk.shape[1]))
        self.caches = eng._seed_chunk(pf.caches, pattern=self.pattern,
                                      batch=chunk.shape[0],
                                      max_len=eng.max_len)
        self.caches, self.logits = eng._commit_state(self.caches,
                                                     pf.logits)
        self.dispatches += 2  # routing prefill + the seed insert


@dataclass
class GenerationResult:
    tokens: np.ndarray            # (B, n_steps)
    routing: Tuple[Any, ...]      # per-layer decode pattern
    msr: float                    # SA fraction over routed layers
    kv_bytes: int                 # decode-cache footprint
    p_fa: Optional[np.ndarray] = None
    dispatches: int = 0           # compiled calls issued for this request
    prefix_hit_tokens: int = 0    # prompt tokens served from a warm prefix


class DrainResult(dict):
    """``{rid: FinishedRequest}`` plus an aggregate ``summary`` dict
    (TTFT split percentiles, prefix hit accounting, and the
    KV/prefix-store occupancy split from ``kv_cache_stats``)."""

    def __init__(self, finished, summary: Dict[str, Any]):
        super().__init__(finished)
        self.summary = summary


class ServeEngine:
    """Single-model serving with flux routing.

    ``routing_override``: force a per-layer pattern (baselines /
    ablations) instead of consulting the router; entries may be "fa",
    "sa", ("duo", n_fa_kv) or None.  ``generate`` also accepts a
    per-request override.

    Decode dispatch discipline: one ``decode_many`` scan per request
    (``dispatch_count`` tracks compiled calls), one executable per
    distinct (cache geometry, n_steps, sampling mode) — two routing
    patterns with the same geometry reuse one executable, and
    ``_check_executable_guard`` raises if a pattern-keyed recompile
    ever sneaks back in.
    """

    def __init__(self, params, cfg: ModelConfig, *, max_len: int = 4096,
                 sparse_decode: bool = True, routing_override=None,
                 decode_attn=None, decode_unroll: int = 4,
                 prefill_chunk: Optional[int] = 512,
                 routing_pooling: str = "prefix",
                 prefix_cache_mb: Optional[float] = None,
                 prefix_cache_host_mb: float = 0.0,
                 slo: Optional[SLO.SLOConfig] = None,
                 telemetry: bool = False,
                 flight_recorder_ticks: int = 512,
                 profile_every: int = 0,
                 fidelity_probe_every: int = 0,
                 memory_ledger: bool = False,
                 mesh=None):
        if routing_pooling not in ("prefix", "prefix_suffix"):
            raise ValueError(
                f"routing_pooling={routing_pooling!r}: expected 'prefix' "
                f"(chunk-invariant serving default) or 'prefix_suffix' "
                f"(the paper's pooling; forces the monolithic prefill)")
        # Tensor-parallel serving (DESIGN.md §Distributed serving):
        # ``mesh`` commits weights tensor-parallel (column/row per
        # launch/shardings.py) and every slot pool head-sharded on the
        # mesh "model" axis; GSPMD propagates, so the per-step
        # collectives stay O(H·D)/O(d_model) activation combines — the
        # cache never moves.  ``mesh=None`` keeps every array
        # uncommitted: bitwise + dispatch-count identical to before.
        self.mesh = mesh
        self._mesh_sig = PSH.mesh_signature(mesh)
        if mesh is not None:
            params = jax.device_put(
                params, SHD.param_shardings_decode_tp(params, mesh))
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self.sparse_decode = sparse_decode
        self.routing_override = routing_override
        self.decode_unroll = decode_unroll
        # max chunk size of the chunked cache-resident prefill; None/0
        # disables it (every admission takes the monolithic fallback)
        self.prefill_chunk = int(prefill_chunk) if prefill_chunk else 0
        self.routing_pooling = routing_pooling
        # shared-prefix radix cache: snapshots at chunk boundaries,
        # device budget prefix_cache_mb (+ optional host offload tier)
        self.prefix_store = self._build_prefix_store(
            prefix_cache_mb, prefix_cache_host_mb)
        # SLO guardrails (serve/slo.py); the default config is all-off.
        # ``sa_level`` is the load-adaptive sparsity rung — 0 (neutral
        # argmax routing) unless a scheduler's LoadTracker turns it.
        self.slo = slo if slo is not None else SLO.SLOConfig()
        self.sa_level = 0
        # attribution layer (DESIGN.md §Observability): sampled cost
        # profiler, routing-fidelity probes and the memory ledger.  All
        # default off; any of them implies the telemetry surfaces exist
        # since they export through the registry/flight recorder.
        if profile_every < 0 or fidelity_probe_every < 0:
            raise ValueError(
                f"profile_every={profile_every} / fidelity_probe_every="
                f"{fidelity_probe_every} must be >= 0 (0 disables)")
        self.profiler = (TM.TickProfiler(profile_every)
                         if profile_every else None)
        self.fidelity_probe_every = int(fidelity_probe_every)
        self._probe_admissions = 0    # admissions seen by the probe dial
        self._params_cost_cache: Optional[Tuple[int, int]] = None
        telemetry = bool(telemetry or profile_every
                         or fidelity_probe_every or memory_ledger)
        # decision-margin drift per (layer, sa_level) rung: pure-host
        # Welford bookkeeping fed by _record_routing, so it rides any
        # telemetry-enabled engine for free
        self.margin_drift = (RT.MarginDriftTracker()
                             if telemetry else None)
        self.ledger = (TM.MemoryLedger(params_bytes=self._params_cost()[1])
                       if memory_ledger else None)
        # serving telemetry (DESIGN.md §Observability): a metrics
        # registry, a request-span tracer and a per-tick flight
        # recorder — all host-side.  Disabled (None) by default: the
        # instrumented paths reduce to ``is not None`` checks, so the
        # off state is bitwise- and executable-guard-identical to an
        # uninstrumented engine (asserted in tests/test_telemetry.py).
        if telemetry:
            self.telemetry: Optional[TM.MetricsRegistry] = \
                TM.MetricsRegistry()
            self.tracer: Optional[TR.SpanTracer] = TR.SpanTracer()
            self.flight_recorder: Optional[TM.FlightRecorder] = \
                TM.FlightRecorder(flight_recorder_ticks)
            self._register_core_metrics()
        else:
            self.telemetry = None
            self.tracer = None
            self.flight_recorder = None
        self._scheduler = None  # lazy ContinuousScheduler (submit/step)
        # optional decode-attention backend (e.g. the Pallas flash-decode
        # kernel via kernels.decode_attention.make_kernel_decode_attn);
        # installed at trace time, baked into the compiled scan.
        self.decode_attn = decode_attn
        # kernel-path accounting: the adapter logs one (hit|decline,
        # reason) per attention layer at *trace* time (lax.scan traces
        # its body once), so the first dispatch of each decode key
        # records the layer decisions and every later dispatch replays
        # them — counters move per compiled call with zero device work.
        self._decode_attn_trace: Dict[Any, Tuple] = {}
        self._decode_kernel_stats: Dict[str, Any] = {
            "dispatches": 0, "hit_layers": 0,
            "decline_layers": Counter()}
        self.dispatch_count = 0           # compiled calls, engine lifetime
        self._decode_keys: set = set()    # expected decode executables
        self._stream_keys: set = set()    # expected (geometry, bucket)
        self._seed_keys: set = set()      # expected chunk-0 seeds
        self._prefill = jax.jit(partial(MD.prefill, cfg=cfg),
                                static_argnames=("routing_ctx",))
        # chunked-prefill executables: keyed by (cache geometry, chunk
        # bucket) — ``start`` is traced, so every offset of a bucket
        # shares one executable and the jit cache stays
        # O(#geometries × #buckets), guard-asserted.
        self._stream_chunk = jax.jit(partial(MD.prefill_chunk, cfg=cfg),
                                     donate_argnames=("caches",))
        self._seed_chunk = jax.jit(
            partial(seed_caches, cfg),
            static_argnames=("pattern", "batch", "max_len"))
        # repack is a long chain of tiny gathers/pads — eager dispatch
        # costs more than the math at serving rates, so compile it per
        # (pattern, seq_len).  Only the monolithic fallback runs it.
        self._repack = jax.jit(
            partial(repack_caches, cfg),
            static_argnames=("routing", "seq_len", "max_len"))
        self._decode_many = jax.jit(
            partial(MD.decode_many, cfg=cfg),
            static_argnames=("n_steps", "greedy", "duo_layers", "unroll"),
            donate_argnames=("caches",))
        # prefix-snapshot copy: one executable per cache geometry,
        # shared between publication (copy before the next chunk
        # donates the live buffers) and restore (copy so a hit never
        # hands the store's own buffers to a donating jit).  The
        # partial wrapper gives each engine its own jit cache — bare
        # ``jax.jit(MD.snapshot_state)`` would share one across
        # engines and break per-engine executable accounting.
        self._snapshot = jax.jit(partial(MD.snapshot_state))
        self._snap_keys: set = set()      # expected snapshot geometries
        self._snap_skip_warned: set = set()
        self._encode = (jax.jit(partial(MD.encode, cfg=cfg))
                        if cfg.num_encoder_layers else None)
        # routing-fidelity probe: FA attention-mass coverage of the
        # routed SA window (MD.attention_mass_coverage), one jitted
        # sweep per power-of-two prompt bucket — ``length`` is traced,
        # so the probe jit cache stays O(log max_len), guard-counted
        # like every other serving-path jit.  Constructing the jit
        # wrapper compiles nothing; with the probe dial at 0 this cache
        # stays empty (asserted by the off-path tests).
        self._coverage = jax.jit(partial(MD.attention_mass_coverage,
                                         cfg=cfg))
        self._probe_keys: set = set()     # expected probe prompt buckets
        # per-geometry expressed-cost specs for the profiler's analytic
        # join (launch/hlo_costs) — derived once per pool from static
        # cache shapes, never from device reads
        self._cost_specs: Dict[Tuple, List[Tuple]] = {}

    def _build_prefix_store(self, prefix_cache_mb,
                            prefix_cache_host_mb) -> Optional[PXC.PrefixStore]:
        if not prefix_cache_mb:
            return None
        cfg = self.cfg
        if not self.prefill_chunk:
            raise ValueError(
                f"prefix_cache_mb={prefix_cache_mb:g} requires the chunked "
                f"prefill: prefix snapshots are chunk-boundary objects and "
                f"the monolithic prefill→repack path has no boundaries to "
                f"snapshot — set prefill_chunk (or drop prefix_cache_mb)")
        override = self.routing_override
        if override is not None and any(isinstance(p, tuple)
                                        for p in override):
            raise ValueError(
                f"prefix_cache_mb={prefix_cache_mb:g} with a duo "
                f"head-split routing_override: duo admissions take the "
                f"repack fallback (chunked_eligible=False), so the store "
                f"could never hold a snapshot — drop the duo override or "
                f"the prefix cache")
        budget = int(prefix_cache_mb * 2 ** 20)
        host_budget = int(prefix_cache_host_mb * 2 ** 20)
        if override is not None:
            pattern = self._pattern(None, override)
            what = "the overridden routing geometry"
        else:
            # smallest geometry the router can pick: SA rings wherever a
            # routed layer may stream — if even that snapshot overflows
            # the budget, no admission could ever publish
            can_sa = cfg.flux.enabled and cfg.flux.sa_mode == "ssa"
            pattern = tuple(
                ("sa" if can_sa else "fa") if k == "attn" else None
                for k in cfg.layer_kinds)
            what = "the smallest routed geometry"
        need = PXC.snapshot_spec_bytes(cfg, pattern, self.max_len)
        if budget < need:
            raise ValueError(
                f"prefix_cache_mb={prefix_cache_mb:g} ({budget} bytes) "
                f"cannot hold one chunk-boundary snapshot for {what} "
                f"({need} bytes at max_len={self.max_len}): raise "
                f"prefix_cache_mb to at least {need / 2 ** 20:.2f} MB or "
                f"lower max_len")
        return PXC.PrefixStore(chunk=self.prefill_chunk,
                               budget_bytes=budget,
                               host_budget_bytes=host_budget)

    # -- routing pattern ---------------------------------------------------
    def _pattern(self, decisions: Optional[np.ndarray],
                 override=None) -> Tuple[Any, ...]:
        cfg = self.cfg
        override = override if override is not None else \
            self.routing_override
        routed = list(cfg.routable_layers())
        pattern: List[Any] = [None] * cfg.num_layers
        for i, kind in enumerate(cfg.layer_kinds):
            if kind != "attn":
                continue
            if not cfg.flux.enabled:
                pattern[i] = "fa"
            elif override is not None:
                pattern[i] = override[i]
            elif decisions is None or not self.sparse_decode:
                pattern[i] = "fa"
            else:
                j = routed.index(i)
                pattern[i] = "fa" if int(decisions[j]) else "sa"
        return tuple(pattern)

    def _routing_ctx(self, override=None):
        """(routing_ctx, fixed_pattern) for an admission prefill.

        No override → hard routing, pooled per ``routing_pooling``; an
        override → the "fixed" context, so SA layers really run sparse
        attention during prefill (the paper's prefill saving) instead of
        full attention followed by a lossy ring truncation."""
        cfg = self.cfg
        override = (override if override is not None
                    else self.routing_override)
        if not (cfg.flux.enabled and cfg.routable_layers()):
            return "fa_only", None
        if override is None:
            return ("hard" if self.routing_pooling == "prefix_suffix"
                    else "hard_prefix"), None
        fixed = jnp.asarray([0 if override[i] == "sa" else 1
                             for i in range(cfg.num_layers)], jnp.int32)
        return "fixed", fixed

    # -- load-adaptive sparsity dial (serve/slo.py) -------------------------
    def set_sa_level(self, level: int) -> None:
        """Set the sparsity rung for *subsequent* admissions (running
        jobs keep the rung they started with).  Clamped to the config's
        quantized ladder, so the reachable pattern — and geometry — set
        stays finite and the executable guard keeps holding."""
        self.sa_level = max(0, min(int(level), self.slo.sa_level_max))

    def fa_threshold(self, level: Optional[int] = None) -> float:
        """FA-decision threshold at ``level`` (default: the current
        rung) on the config's ladder."""
        lv = self.sa_level if level is None else level
        return RT.sa_biased_threshold(lv, step=self.slo.sa_threshold_step,
                                      max_level=self.slo.sa_level_max)

    # -- telemetry (DESIGN.md §Observability) -------------------------------
    def _register_core_metrics(self) -> None:
        """Pre-register the always-present metrics so ``metrics_text``
        exposes a stable schema from the first scrape (gauges read 0
        until the scheduler ticks), and hook the prefix store's
        eviction events into the registry."""
        reg = self.telemetry
        reg.gauge("flux_sa_level",
                  "load-adaptive sparsity rung (0 = neutral routing)")
        reg.gauge("flux_load_pressure",
                  "LoadTracker queue-pressure signal in [0, 1]")
        reg.gauge("serve_queue_depth", "waiting requests after admission")
        reg.gauge("serve_slots_active", "resident decode slots, all pools")
        reg.gauge("serve_slots_capacity", "total decode slots, all pools")
        reg.counter("serve_ticks_total", "scheduler ticks")
        reg.counter("serve_tokens_generated_total",
                    "tokens accepted from decode chunks")
        reg.counter("serve_requests_submitted_total", "requests submitted")
        reg.counter("serve_prefill_chunks_total",
                    "prefill chunks streamed as tick work")
        reg.counter("serve_preemptions_total", "recompute preemptions")
        reg.counter("serve_dispatches_total", "compiled calls issued")
        reg.counter("flux_sa_transitions_total",
                    "sparsity-dial rung changes, either direction")
        reg.gauge("prefix_store_device_bytes",
                  "prefix snapshot store occupancy, device tier")
        reg.gauge("prefix_store_host_bytes",
                  "prefix snapshot store occupancy, host tier")
        for status in SLO.STATUSES:
            reg.counter("serve_requests_finished_total",
                        "retired requests by terminal status",
                        status=status)
        # decode-kernel path counters (ISSUE 8: no more silent decline)
        # — pre-registered with the adapter's decline vocabulary so the
        # scrape schema is stable even before the first decode
        reg.counter("decode_kernel_hit_layers_total",
                    "attention layers served by the decode kernel, "
                    "accumulated per compiled decode call")
        for reason in DECODE_KERNEL_DECLINE_REASONS:
            reg.counter("decode_kernel_decline_layers_total",
                        "attention layers where the kernel adapter "
                        "declined and dense decode ran instead",
                        reason=reason)
        # per-layer FA/SA decision counters exist from the first scrape
        # so dashboards see every routed layer, not just the ones the
        # traffic so far happened to exercise
        for i in self.cfg.routable_layers():
            for d in ("fa", "sa"):
                reg.counter("flux_router_decisions_total",
                            "hard routing decisions at admission time",
                            layer=str(i), decision=d)
        # fidelity probes opt in per engine; pre-register their
        # histograms so the scrape schema is stable before the first
        # probe admission fires
        if self.fidelity_probe_every:
            for i in self.cfg.routable_layers():
                for d in ("fa", "sa"):
                    reg.histogram(
                        "flux_fidelity_coverage",
                        "fraction of the full-attention mass of the last "
                        "prompt token retained by the routed SA window, "
                        "per routed layer (probe admissions only)",
                        layer=str(i), decision=d)
        if self.prefix_store is not None:
            self.prefix_store.on_event = self._prefix_store_event

    def _prefix_store_event(self, event: str) -> None:
        self.telemetry.counter("prefix_store_events_total",
                               "prefix store lifecycle events",
                               event=event).inc()

    def _record_routing(self, pattern, p_fa: Optional[np.ndarray],
                        sa_level: int) -> None:
        """Count per-layer FA/SA decisions and threshold-vs-score
        margins for one admission.  Called where the routing decision
        lands on host anyway (``np.asarray(pf.routing)`` in the
        admission paths), so this reads already-materialized host state
        and never adds a device sync."""
        reg = self.telemetry
        if reg is None or pattern is None:
            return
        routed = self.cfg.routable_layers()
        for j, i in enumerate(routed):
            d = pattern[i]
            if d not in ("fa", "sa"):
                continue  # duo head-splits have no binary decision
            reg.counter("flux_router_decisions_total",
                        layer=str(i), decision=d).inc()
            if p_fa is not None and j < len(p_fa):
                margin = RT.decision_margin(
                    float(p_fa[j]), sa_level,
                    step=self.slo.sa_threshold_step,
                    max_level=self.slo.sa_level_max)
                reg.histogram(
                    "flux_router_margin",
                    "router p_fa minus the (possibly SA-biased) decision "
                    "threshold; positive = FA side",
                    layer=str(i)).observe(margin)
                if self.margin_drift is not None:
                    # same already-materialized host float — drift
                    # tracking is keyed by the admission's rung, so the
                    # sparsity dial gets per-rung traffic-shift signals
                    self.margin_drift.observe(i, sa_level, margin)

    def _refresh_gauges(self) -> None:
        """Point-in-time gauges from host state (scheduler occupancy,
        prefix store tiers, sparsity dial) — called per scheduler tick
        and at scrape time so ``metrics_text`` is current even between
        ticks."""
        reg = self.telemetry
        reg.gauge("flux_sa_level").set(self.sa_level)
        if self.prefix_store is not None:
            reg.gauge("prefix_store_device_bytes").set(
                self.prefix_store.device_bytes)
            reg.gauge("prefix_store_host_bytes").set(
                self.prefix_store.host_bytes)
        sched = self._scheduler
        if sched is not None:
            reg.gauge("flux_load_pressure").set(sched.load.pressure)
            reg.gauge("serve_queue_depth").set(len(sched.waiting))
            reg.gauge("serve_slots_active").set(sched.n_active())
            reg.gauge("serve_slots_capacity").set(
                sum(p.capacity for p in sched.pools.values()))
        md = self.margin_drift
        if md is not None:
            for layer, level in md.keys():
                reg.gauge(
                    "flux_router_margin_drift",
                    "recent-minus-lifetime mean router decision margin, "
                    "per (layer, sparsity rung) — nonzero means the "
                    "traffic mix shifted under a fixed dial setting",
                    layer=str(layer), sa_level=str(level)).set(
                        md.drift(layer, level))
        led = self.ledger
        snap = led.last() if led is not None else None
        if snap is not None:
            reg.gauge("serve_ledger_device_bytes",
                      "memory ledger: tracked device bytes (pools + "
                      "prefix device tier + params)").set(snap.device_bytes)
            reg.gauge("serve_ledger_pool_live_bytes",
                      "memory ledger: payload bytes in occupied slots"
                      ).set(snap.pool_live_bytes)
            reg.gauge("serve_ledger_pool_stranded_bytes",
                      "memory ledger: payload bytes in empty slots"
                      ).set(snap.pool_stranded_bytes)
            reg.gauge("serve_ledger_fragmentation_bytes",
                      "memory ledger: empty-slot bytes in pools whose "
                      "geometry matches no queued work").set(
                          snap.fragmentation_bytes)
            reg.gauge("serve_ledger_device_high_watermark_bytes",
                      "memory ledger: lifetime peak of tracked device "
                      "bytes").set(led.high_watermark)

    def metrics_text(self) -> str:
        """Current metrics as Prometheus text exposition format."""
        if self.telemetry is None:
            raise ValueError(
                "metrics_text: telemetry is disabled — construct the "
                "ServeEngine with telemetry=True (or pass --metrics-out "
                "to launch/serve.py)")
        self._refresh_gauges()
        return self.telemetry.render()

    def export_trace(self, path: str) -> None:
        """Write the request-span trace as Chrome-trace/Perfetto JSON
        (open in chrome://tracing or https://ui.perfetto.dev)."""
        if self.tracer is None:
            raise ValueError(
                "export_trace: telemetry is disabled — construct the "
                "ServeEngine with telemetry=True (or pass --trace-out "
                "to launch/serve.py)")
        self.tracer.export(path)

    # -- tensor-parallel state normalization --------------------------------
    def mesh_shape(self) -> Optional[Tuple[int, ...]]:
        """Axis sizes of the serving mesh (telemetry's TickRecord shape);
        None on the single-device path."""
        if self.mesh is None:
            return None
        return tuple(int(self.mesh.shape[a]) for a in self.mesh.axis_names)

    def _commit_state(self, caches, logits):
        """Commit (caches, logits) to the pool shardings — k/v
        head-sharded on "model", everything else replicated.  Called at
        every producer boundary of the admission pipeline (seed, stream
        chunk, prefix restore) so each consumer jit (stream, snapshot,
        slot write, decode) sees exactly ONE input sharding per
        geometry: compiler-chosen output shardings would otherwise vary
        between the fresh-prefill and warm-restore paths and split jit
        entries, breaking the O(#geometries) guard.  A no-op when the
        state already carries the target shardings, and the identity on
        the mesh=None path."""
        if self.mesh is None:
            return caches, logits
        caches = PSH.shard_pool_caches(caches, self.mesh)
        if logits is not None:
            logits = PSH.replicate(logits, self.mesh)
        return caches, logits

    # -- decode-attention backend (kernel-path accounting) -----------------
    def _attn_ctx(self):
        """Context installing the engine's decode-attention backend
        around one compiled decode call (nullcontext when none is
        configured).  Every decode site — ``generate`` and the
        scheduler's pooled tick — must go through this so the ambient
        override state is consistent with what each executable was
        traced under."""
        if self.decode_attn is None:
            return contextlib.nullcontext()
        return MD.use_decode_attn(self.decode_attn)

    def _note_decode_dispatch(self, key) -> None:
        """Account one decode dispatch against the kernel-path
        counters.  A dispatch that traced (jit cache miss) drains the
        adapter's trace log — one (hit|decline, reason) entry per
        attention layer — and records it under ``key``; cached
        dispatches replay the recorded decisions."""
        fn = self.decode_attn
        if fn is None:
            return
        st = self._decode_kernel_stats
        st["dispatches"] += 1
        if not hasattr(fn, "drain_log"):
            return  # legacy backend: no per-layer decision log
        fresh = fn.drain_log()
        if fresh:
            self._decode_attn_trace[key] = tuple(fresh)
        reg = self.telemetry
        for event, reason in self._decode_attn_trace.get(key, ()):
            if event == "hit":
                st["hit_layers"] += 1
                if reg is not None:
                    reg.counter("decode_kernel_hit_layers_total").inc()
            else:
                st["decline_layers"][reason] += 1
                if reg is not None:
                    reg.counter("decode_kernel_decline_layers_total",
                                reason=reason).inc()

    def decode_kernel_summary(self) -> Dict[str, Any]:
        """Kernel-path accounting over the engine's lifetime: compiled
        decode dispatches, and per-layer hit/decline(reason) tallies
        replayed from the adapters' trace-time decisions."""
        st = self._decode_kernel_stats
        return {
            "installed": self.decode_attn is not None,
            "dispatches": st["dispatches"],
            "hit_layers": st["hit_layers"],
            "decline_layers": dict(st["decline_layers"]),
        }

    # -- cost attribution (profiler / ledger / probes) ----------------------
    def _params_cost(self) -> Tuple[int, int]:
        """(parameter count, parameter bytes) — shape metadata only,
        walked once and cached (the analytic linear-cost term and the
        ledger's params line both read it)."""
        pc = self._params_cost_cache
        if pc is None:
            leaves = jax.tree_util.tree_leaves(self.params)
            pc = self._params_cost_cache = (
                int(sum(l.size for l in leaves)),
                int(sum(l.size * l.dtype.itemsize for l in leaves)))
        return pc

    def device_sync(self, *trees) -> None:
        """Timed sync boundary for the profiler's sampled tick path:
        block until every array in ``trees`` is ready.  ONLY sampled
        ticks may call this — the unsampled path must stay sync-free
        (DESIGN.md §Observability sampling rules)."""
        jax.block_until_ready([t for t in trees if t is not None])

    def _pool_layer_specs(self, pool) -> List[Tuple]:
        """Per-attention-layer (buffer_len, n_q_heads, n_kv_heads, d_k,
        d_v, dtype_bytes) specs for one slot pool, from static cache
        shapes — the geometry half of the hlo_costs expressed-cost
        join.  Cached per slot geometry."""
        key = pool.slot_geometry()
        specs = self._cost_specs.get(key)
        if specs is None:
            hq = self.cfg.num_heads
            specs = []
            for c in pool.caches:
                if isinstance(c, KC.MambaCache):
                    continue
                if isinstance(c, (KC.LatentKV, KC.RingLatentKV)):
                    # absorbed MLA decode: one latent "kv head", scores
                    # over ckv+rope, values read from the latent
                    specs.append((c.ckv.shape[1], hq, 1,
                                  c.ckv.shape[-1] + c.kr.shape[-1],
                                  c.ckv.shape[-1], c.ckv.dtype.itemsize))
                else:  # FullKV / RingKV: k is (slots, Hkv, L, D)
                    specs.append((c.k.shape[2], hq, c.k.shape[1],
                                  c.k.shape[-1], c.v.shape[-1],
                                  c.k.dtype.itemsize))
            self._cost_specs[key] = specs
        return specs

    def _expressed_decode_cost(self, pool, dk_key, n_steps: int
                               ) -> Dict[str, Any]:
        """Analytic expressed FLOPs/HBM bytes for ``n_steps`` pooled
        decode steps on ``pool`` (hlo_costs counting conventions),
        joined with the kernel-path trace for ``dk_key`` so kernel-hit
        layers cost their live-length block trips and declined/dense
        layers cost the full buffer sweep.  Host arithmetic over static
        shapes and host-known lengths — never a device read."""
        specs = self._pool_layer_specs(pool)
        lengths = [1] * pool.capacity  # free rows park at position 0
        for slot, inf in pool.active.items():
            lengths[slot] = max(
                1, inf.metrics.prompt_len + len(inf.generated))
        trace = self._decode_attn_trace.get(dk_key, ())
        hits = ([e == "hit" for e, _ in trace]
                if len(trace) == len(specs) else None)
        attn = HL.pooled_decode_tick_cost(lengths, specs,
                                          n_steps=n_steps,
                                          kernel_hits=hits)
        n_params, params_bytes = self._params_cost()
        lin = HL.decode_linear_cost(n_params, params_bytes,
                                    batch=pool.capacity, n_steps=n_steps)
        return {
            "flops": attn["flops"] + lin["flops"],
            "hbm_bytes": attn["hbm_bytes"] + lin["hbm_bytes"],
            "kernel_hit": attn["kernel_hit"],
            "kernel_decline": attn["kernel_decline"],
        }

    def _maybe_fidelity_probe(self, tokens_1d, pattern
                              ) -> Optional[np.ndarray]:
        """Every ``fidelity_probe_every``-th admission becomes a probe
        request: one extra jitted sweep (MD.attention_mass_coverage)
        measures, per routed layer, the fraction of the FA attention
        mass of the last prompt token that the routed SA window
        retains.  The prompt pads to its power-of-two bucket with a
        traced true length — bitwise-identical coverage to the unpadded
        form, and O(log max_len) probe executables.  Probe admissions
        pay one dispatch plus a host sync; with the dial at 0 this
        method is a single int test."""
        if not self.fidelity_probe_every:
            return None
        self._probe_admissions += 1
        if (self._probe_admissions - 1) % self.fidelity_probe_every:
            return None
        routed = self.cfg.routable_layers()
        if not routed:
            return None
        toks = np.asarray(tokens_1d).reshape(-1)
        S = int(toks.size)
        if S < 1:
            return None
        bucket = 1 if S <= 1 else 1 << (S - 1).bit_length()
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :S] = toks.astype(np.int32)
        self._probe_keys.add(bucket)
        cov = self._coverage(self.params, tokens=jnp.asarray(padded),
                             length=jnp.int32(S))
        self.dispatch_count += 1
        cov = np.asarray(cov)
        reg = self.telemetry
        if reg is not None:
            for j, i in enumerate(routed):
                if j < cov.size and pattern[i] in ("fa", "sa"):
                    reg.histogram("flux_fidelity_coverage",
                                  layer=str(i),
                                  decision=pattern[i]).observe(
                                      float(cov[j]))
        return cov

    def ledger_report(self) -> Dict[str, Any]:
        """Fresh memory-ledger snapshot reconciled against an
        independent ``kv_cache_stats`` walk of the same pools + prefix
        store.  Pool payload and prefix tiers must agree exactly; the
        ledger's overhead exceeds kv_cache_stats by exactly the
        pool-level aux buffers (logits/pos) the cache walk never sees —
        ``reconciliation`` carries the deltas so callers can assert."""
        if self.ledger is None:
            raise ValueError(
                "ledger_report: the memory ledger is disabled — construct "
                "the ServeEngine with memory_ledger=True (or pass "
                "--ledger-out to launch/serve.py)")
        sched = self._scheduler
        snap = (sched.ledger_snapshot() if sched is not None
                else self.ledger.last())
        pools = list(sched.pools.values()) if sched is not None else []
        stats = kv_cache_stats([p.caches for p in pools],
                               self.prefix_store)
        out: Dict[str, Any] = {
            "snapshot": snap.as_dict() if snap is not None else None,
            "kv_cache_stats": {
                "payload_bytes": stats.payload_bytes,
                "overhead_bytes": stats.overhead_bytes,
                "prefix_device_bytes": stats.prefix_device_bytes,
                "prefix_host_bytes": stats.prefix_host_bytes,
                # per-device bytes: < global for mesh-sharded pools
                # (k/v divide by the "model" axis), == global on one
                # device.  Reconciliation stays on the global figures.
                "payload_shard_bytes": stats.payload_shard_bytes,
                "overhead_shard_bytes": stats.overhead_shard_bytes,
            },
            "mesh": (list(self.mesh_shape())
                     if self.mesh is not None else None),
            "reconciliation": None,
            "aux_bytes": 0,
        }
        if snap is not None:
            out["reconciliation"] = snap.reconcile(
                stats.payload_bytes, stats.overhead_bytes,
                stats.prefix_device_bytes, stats.prefix_host_bytes)
            out["aux_bytes"] = sum(p.aux_bytes for p in snap.pools)
        if self.prefix_store is not None:
            out["prefix_store"] = self.prefix_store.stats().as_dict()
        return out

    def profiler_report(self) -> Dict[str, Any]:
        """The sampled cost profiler's achieved-vs-expressed table."""
        if self.profiler is None:
            raise ValueError(
                "profiler_report: the tick profiler is disabled — "
                "construct the ServeEngine with profile_every=N (or pass "
                "--profile-every to launch/serve.py)")
        return self.profiler.report()

    def attribution_report(self) -> Dict[str, Any]:
        """Everything the attribution layer knows, JSON-ready: the
        profiler table, the reconciled ledger, decision-margin drift
        and kernel-path accounting.  Disabled parts report None."""
        return {
            "profiler": (self.profiler.report()
                         if self.profiler is not None else None),
            "ledger": (self.ledger_report()
                       if self.ledger is not None else None),
            "margin_drift": (self.margin_drift.report()
                             if self.margin_drift is not None else None),
            "decode_kernel": self.decode_kernel_summary(),
            "fidelity_probe_every": self.fidelity_probe_every,
            "probe_admissions": self._probe_admissions,
        }

    # -- jit-cache bookkeeping ---------------------------------------------
    def decode_cache_size(self) -> int:
        """Number of compiled decode executables held by this engine."""
        return self._decode_many._cache_size()

    def prefill_chunk_cache_size(self) -> int:
        """Compiled stream-chunk executables held by this engine."""
        return self._stream_chunk._cache_size()

    def prefix_restore_cache_size(self) -> int:
        """Compiled snapshot copy/restore executables (O(#geometries):
        publication and restore of one geometry share the entry)."""
        return self._snapshot._cache_size()

    def _check_executable_guard(self) -> None:
        """Every serving-path jit cache must stay geometry-bounded —
        decode at O(#geometries), the chunked-prefill stream and seed at
        O(#geometries × #chunk-buckets) — never O(2^routable_layers)
        pattern-keyed entries."""
        compiled, expected = self.decode_cache_size(), len(self._decode_keys)
        if compiled > expected:
            raise RuntimeError(
                f"decode executable explosion: {compiled} compiled for "
                f"{expected} (geometry, n_steps, sampling) keys — a "
                f"routing-pattern-static argument has leaked into the "
                f"decode jit signature")
        for jitted, keys, name in (
                (self._stream_chunk, self._stream_keys, "stream-chunk"),
                (self._seed_chunk, self._seed_keys, "chunk-0 seed")):
            compiled = jitted._cache_size()
            if compiled > len(keys):
                raise RuntimeError(
                    f"{name} executable explosion: {compiled} compiled "
                    f"for {len(keys)} (geometry, chunk-bucket) keys — a "
                    f"non-bucketed chunk size or pattern-static argument "
                    f"has leaked into the chunked-prefill jit signature")
        compiled = self._snapshot._cache_size()
        if compiled > len(self._snap_keys):
            raise RuntimeError(
                f"prefix-snapshot executable explosion: {compiled} "
                f"compiled for {len(self._snap_keys)} geometry keys — "
                f"the snapshot copy/restore jit must stay O(#geometries) "
                f"(publication and restore of one geometry share an "
                f"executable); something pattern- or length-shaped has "
                f"leaked into its signature")
        compiled = self._coverage._cache_size()
        if compiled > len(self._probe_keys):
            raise RuntimeError(
                f"fidelity-probe executable explosion: {compiled} "
                f"compiled for {len(self._probe_keys)} prompt buckets — "
                f"probe prompts must pad to power-of-two buckets with a "
                f"traced length (O(log max_len) executables), never "
                f"trace per prompt length")

    # -- admission: chunked hot path --------------------------------------
    def chunked_eligible(self, seq_len: int, override=None, *,
                         prefix_embeddings=None,
                         encoder_frames=None) -> bool:
        """True when the chunked cache-resident admission can serve this
        request; False routes it to the monolithic repack fallback."""
        cfg = self.cfg
        if not self.prefill_chunk or seq_len <= 0:
            return False
        if (prefix_embeddings is not None or encoder_frames is not None
                or cfg.num_encoder_layers or cfg.num_prefix_tokens):
            return False  # modality side inputs ride the monolithic path
        override = (override if override is not None
                    else self.routing_override)
        if override is not None and any(isinstance(p, tuple)
                                        for p in override):
            return False  # duo head-splits keep the repack path
        routable = bool(cfg.flux.enabled and cfg.routable_layers())
        if routable and override is None:
            if not self.sparse_decode:
                # decisions would diverge from geometry (the ablation
                # rows where SA prefill feeds a full decode cache)
                return False
            if self.routing_pooling != "prefix":
                return False  # paper pooling needs the full sequence
            if (chunk_plan(seq_len, self.prefill_chunk)[0][1]
                    < min(cfg.flux.pool_size, seq_len)):
                return False  # first chunk can't cover the router pool
        needs_sa = routable and (override is None
                                 or any(p == "sa" for p in override))
        if needs_sa and cfg.flux.sa_mode != "ssa":
            return False  # xa/ta prefill has no ring-resident equivalent
        return True

    def start_chunked_prefill(self, tokens: jax.Array, override=None, *,
                              reuse: bool = True) -> ChunkedPrefill:
        """Begin a route-then-stream admission; the caller drives
        ``job.step()`` (the continuous scheduler interleaves steps with
        decode ticks; ``prefill_chunked`` runs them back-to-back).

        When the engine has a prefix store and ``reuse`` holds, the job
        starts from the deepest matching chunk-boundary snapshot: its
        covered chunks are skipped outright (``prefix_hit_tokens``) and
        only the uncovered suffix streams.  ``reuse=False`` opts the
        request out of both lookup and publication."""
        tokens = jnp.asarray(tokens)
        job = ChunkedPrefill(
            engine=self, tokens=tokens,
            override=(override if override is not None
                      else self.routing_override),
            plan=chunk_plan(tokens.shape[1], self.prefill_chunk),
            reuse=reuse, sa_level=self.sa_level)
        if (self.prefix_store is not None and reuse
                and tokens.shape[0] == 1
                and self.chunked_eligible(tokens.shape[1], job.override)):
            self._try_prefix_restore(job)
        return job

    def prefill_chunked(self, tokens: jax.Array, override=None, *,
                        reuse: bool = True) -> ChunkedPrefill:
        """The chunked admission run to completion.  Returns the
        finished job (``pattern``/``caches``/``logits``/``p_fa``)."""
        job = self.start_chunked_prefill(tokens, override, reuse=reuse)
        while not job.done:
            job.step()
        return job

    # -- shared-prefix snapshot reuse (DESIGN.md §Prefix cache) -------------
    def _routable(self) -> bool:
        return bool(self.cfg.flux.enabled and self.cfg.routable_layers())

    def _snap_sig(self, caches, logits) -> Tuple:
        return (KC.cache_geometry(caches), _arr_sig(logits),
                self._mesh_sig)

    def _restore_state(self, node: PXC._Node):
        """Snapshot → fresh device buffers the admission may own (and
        later donate).  Host-tier snapshots prefetch to device and are
        promoted in place (the next hit skips the transfer); either
        tier then hits the same per-geometry copy executable
        (uncommitted inputs on the single-device path; under a mesh,
        the pool shardings the whole admission pipeline is normalized
        to), so restores stay O(#geometries) (guard-asserted)."""
        snap = node.snap
        if self.mesh is not None:
            # commit to the pool shardings — the same flavor every
            # other producer boundary emits, so the snapshot copy jit
            # keeps one entry per geometry under the mesh too
            caches, logits = self._commit_state(snap.caches, snap.logits)
        else:
            # deviceless device_put: prefetches host (numpy) tiers to
            # the default device and is a no-op for device tiers —
            # either way the result is *uncommitted*, keying the same
            # jit entry
            caches, logits = jax.device_put((snap.caches, snap.logits))
        if node.on_host:
            # the prefetched copy is nobody else's buffer (the job only
            # ever receives the jit copy below) — hand it to the store
            self.prefix_store.promote(node, caches, logits)
        self._snap_keys.add(self._snap_sig(caches, logits))
        return self._snapshot(caches, logits)

    def _try_prefix_restore(self, job: ChunkedPrefill) -> None:
        """Longest-prefix-match ``job``'s prompt against the store and,
        on a hit, seed the job from the snapshot: caches/logits/pattern
        adopted, ``idx`` advanced past every covered chunk."""
        store, cfg = self.prefix_store, self.cfg
        toks = np.asarray(job.tokens[0])
        node = store.match(toks, PXC.routing_key(job.override,
                                                 job.sa_level))
        if (node is not None and job.override is None
                and not RT.prefix_routing_reusable(
                    cfg.flux, node.depth, toks.size,
                    routable=self._routable())):
            node = None  # routing not prefix-determined for this pair
        if node is None:
            store.misses += 1
            if self.telemetry is not None:
                self._prefix_store_event("miss")
            return
        store.acquire(node)  # pin against eviction while restoring
        try:
            snap = node.snap
            job.caches, job.logits = self._restore_state(node)
        finally:
            store.release(node)
        store.hits += 1
        store.hit_tokens += snap.boundary
        if self.telemetry is not None:
            self._prefix_store_event("hit")
        job.pattern = snap.pattern
        job.p_fa = None if snap.p_fa is None else np.array(snap.p_fa)
        job._geom = KC.cache_geometry(job.caches)
        job.idx = snap.boundary // self.prefill_chunk
        job.prefix_hit_tokens = snap.boundary
        job.dispatches += 1  # the restore copy

    def _maybe_publish(self, job: ChunkedPrefill, start: int,
                       size: int) -> None:
        """Publish the boundary the job just crossed, when canonical:
        B=1, a *full*-chunk boundary (ragged ladder tails differ per
        prompt length and are never shared), and — router-driven — a
        prefix the routing decision actually transfers across."""
        store = self.prefix_store
        if (store is None or not job.reuse or job.tokens.shape[0] != 1
                or size != self.prefill_chunk
                or not self.chunked_eligible(job.seq_len, job.override)):
            return
        toks = np.asarray(job.tokens[0])
        if self.publish_prefix(toks, start + size, job.caches, job.logits,
                               job.pattern, p_fa=job.p_fa,
                               override=job.override,
                               sa_level=job.sa_level):
            job.dispatches += 1  # the snapshot copy
            job.published += 1

    def publish_prefix(self, tokens, boundary: int, caches, logits,
                       pattern, p_fa=None, override=None,
                       sa_level: int = 0) -> bool:
        """Insert a chunk-boundary snapshot of ``tokens[:boundary]``
        into the prefix store.  Returns True iff a snapshot was
        actually copied and inserted (False: duplicate, non-transferable
        routing, or an over-budget geometry — skipped with a warning).

        Raises ``ValueError`` for states that are not chunk-boundary
        snapshots at all: publication from a repack-fallback admission
        (``chunked_eligible`` False — full-sequence repack state has no
        boundary snapshots, and ``routing_ctx="hard"`` decisions depend
        on the prompt suffix) or a boundary off the full-chunk grid."""
        store = self.prefix_store
        if store is None:
            raise ValueError(
                "publish_prefix: engine has no prefix store — construct "
                "the ServeEngine with prefix_cache_mb")
        toks = np.asarray(tokens)
        override = override if override is not None else \
            self.routing_override
        if not self.chunked_eligible(toks.size, override):
            raise ValueError(
                f"publish_prefix: this admission takes the monolithic "
                f"repack fallback (chunked_eligible=False for seq_len="
                f"{toks.size}), which has no chunk-boundary state to "
                f"snapshot — its caches are a full-sequence repack and "
                f"its routing may depend on the prompt suffix.  Serve "
                f"the request through the chunked path (prefill_chunk "
                f"set, prefix-pooled routing, no duo/modality inputs) "
                f"or skip publication for it")
        if (boundary <= 0 or boundary > toks.size
                or boundary % self.prefill_chunk):
            raise ValueError(
                f"publish_prefix: boundary={boundary} is not a full-chunk "
                f"plan boundary of a length-{toks.size} prompt (chunk="
                f"{self.prefill_chunk}) — snapshots are shareable only at "
                f"multiples of the chunk size")
        if override is None and not RT.prefix_routing_reusable(
                self.cfg.flux, boundary, toks.size,
                routable=self._routable()):
            return False  # decision pooled from tokens past the boundary
        key = PXC.routing_key(override, sa_level)
        if store.covered(toks, boundary, key):
            return False  # already published (LRU slot bumped)
        nbytes = PXC.state_bytes(caches, logits)
        if nbytes > store.budget_bytes + store.host_budget_bytes:
            geom = self._snap_sig(caches, logits)
            if geom not in self._snap_skip_warned:
                self._snap_skip_warned.add(geom)
                warnings.warn(
                    f"prefix cache: one snapshot of this routed geometry "
                    f"({nbytes} bytes) exceeds the whole store budget "
                    f"({store.budget_bytes + store.host_budget_bytes} "
                    f"bytes); skipping publication — raise "
                    f"prefix_cache_mb to cache these admissions")
            return False
        self._snap_keys.add(self._snap_sig(caches, logits))
        snap_caches, snap_logits = self._snapshot(caches, logits)
        store.insert(toks, PXC.Snapshot(
            caches=snap_caches, logits=snap_logits, pattern=pattern,
            p_fa=None if p_fa is None else np.array(p_fa),
            boundary=boundary, nbytes=nbytes), key)
        return True

    # -- admission: monolithic fallback ------------------------------------
    def prefill_route_repack(self, tokens: jax.Array, override=None, *,
                             prefix_embeddings=None, encoder_frames=None):
        """Monolithic admission FALLBACK: full-sequence prefill (router
        fires once) → per-request pattern → host-planned repack into
        decode geometry.  The hot path is ``prefill_chunked``; this
        path materializes O(S) KV at every layer and is retained only
        for what the chunked pipeline excludes (``chunked_eligible``):
        ``routing_ctx="hard"`` soft-metric runs needing the paper's
        prefix+suffix pooling / full-sequence p_fa, modality side
        inputs, duo head-split overrides, and non-ssa SA modes.
        Returns (pf, pattern, caches, seq_len)."""
        override = (override if override is not None
                    else self.routing_override)
        routing_ctx, fixed = self._routing_ctx(override)
        thr = (jnp.float32(self.fa_threshold())
               if self.sa_level > 0
               and routing_ctx in ("hard", "hard_prefix") else None)
        pf = self._prefill(params=self.params, tokens=tokens,
                           routing_ctx=routing_ctx, fixed_pattern=fixed,
                           prefix_embeddings=prefix_embeddings,
                           encoder_frames=encoder_frames,
                           fa_threshold=thr)
        decisions = (np.asarray(pf.routing)
                     if pf.routing is not None else None)
        pattern = self._pattern(decisions, override)
        if self.telemetry is not None:
            self._record_routing(
                pattern,
                None if pf.p_fa is None else np.asarray(pf.p_fa),
                self.sa_level)
        seq_len = tokens.shape[1] + (prefix_embeddings.shape[1]
                                     if prefix_embeddings is not None else 0)
        if seq_len > self.max_len:
            # fail here, loudly, instead of at repack trace depth: ring
            # layers truncate long prompts structurally but full-cache
            # layers cannot hold them at all.
            off = [i for i, k in enumerate(self.cfg.layer_kinds)
                   if k == "attn" and pattern[i] != "sa"]
            if off:
                raise ValueError(
                    f"prefill_route_repack: prompt length seq_len="
                    f"{seq_len} exceeds the decode cache capacity "
                    f"max_len={self.max_len} at full-cache layer "
                    f"{off[0]}; raise the engine's max_len or truncate "
                    f"the prompt")
        caches = self._repack(pf.caches, routing=pattern,
                              seq_len=seq_len, max_len=self.max_len)
        return pf, pattern, caches, seq_len

    def generate(self, tokens: np.ndarray, n_steps: int, *,
                 prefix_embeddings=None, encoder_frames=None,
                 greedy: bool = True, rng=None,
                 routing_override=None,
                 prefix_reuse: bool = True) -> GenerationResult:
        cfg = self.cfg
        tokens = jnp.asarray(tokens)
        seq_len = tokens.shape[1] + (prefix_embeddings.shape[1]
                                     if prefix_embeddings is not None else 0)
        if seq_len > self.max_len:
            raise ValueError(
                f"generate: prompt length {seq_len} exceeds the engine's "
                f"cache capacity max_len={self.max_len}; raise max_len "
                f"or truncate the prompt")
        dispatches = 0
        enc_out = None
        if self._encode is not None:
            enc_out = self._encode(params=self.params, frames=encoder_frames)
            dispatches += 1
        prefix_hit = 0
        if self.chunked_eligible(seq_len, routing_override,
                                 prefix_embeddings=prefix_embeddings,
                                 encoder_frames=encoder_frames):
            job = self.prefill_chunked(tokens, routing_override,
                                       reuse=prefix_reuse)
            pattern, caches = job.pattern, job.caches
            logits, p_fa = job.logits, job.p_fa
            dispatches += job.dispatches
            prefix_hit = job.prefix_hit_tokens
        else:
            pf, pattern, caches, seq_len = self.prefill_route_repack(
                tokens, routing_override,
                prefix_embeddings=prefix_embeddings,
                encoder_frames=encoder_frames)
            logits = pf.logits
            p_fa = None if pf.p_fa is None else np.asarray(pf.p_fa)
            dispatches += 2  # prefill + the jitted repack
            # the monolithic repack emits compiler-chosen shardings
            # under a mesh; normalize so decode sees the pool flavor
            caches, logits = self._commit_state(caches, logits)
        if (seq_len + n_steps > self.max_len
                and any(isinstance(c, (KC.FullKV, KC.LatentKV))
                        for c in caches)):
            raise ValueError(
                f"generate: prompt ({seq_len}) + n_steps ({n_steps}) = "
                f"{seq_len + n_steps} exceeds the cache capacity "
                f"max_len={self.max_len}; full-cache layers would "
                f"silently clamp decode appends")
        kv_bytes = kv_cache_bytes(caches)

        greedy = bool(greedy or rng is None)
        rng = rng if rng is not None else jax.random.key(0)
        fa_heads, duo_layers = MD.routing_head_split(cfg, pattern)
        pos = jnp.int32(seq_len)
        dk = decode_executable_key(caches, pos, n_steps, greedy,
                                   duo_layers, enc_out, rng,
                                   mesh_sig=self._mesh_sig)
        self._decode_keys.add(dk)
        with warnings.catch_warnings(), self._attn_ctx():
            # donation is a no-op on backends without buffer aliasing
            # (CPU tests) — harmless, silence the per-call warning
            warnings.filterwarnings("ignore", message=".*[Dd]onat.*")
            toks, _, _ = self._decode_many(
                params=self.params, logits=logits, caches=caches,
                pos=pos, rng=rng, n_steps=n_steps,
                greedy=greedy, enc_out=enc_out, fa_heads=fa_heads,
                duo_layers=duo_layers, unroll=self.decode_unroll)
        self._note_decode_dispatch(dk)
        dispatches += 1
        self.dispatch_count += dispatches
        self._check_executable_guard()
        routed = [p for p in pattern if p is not None]
        msr_val = (sum(p == "sa" for p in routed) / len(routed)
                   if routed else float("nan"))
        return GenerationResult(
            tokens=np.asarray(toks), routing=pattern,
            msr=msr_val, kv_bytes=kv_bytes,
            p_fa=p_fa, dispatches=dispatches,
            prefix_hit_tokens=prefix_hit)

    # -- continuous-batching (streaming) frontend ---------------------------
    def scheduler(self, **kw):
        """The engine's ``ContinuousScheduler`` (created on first use;
        kwargs configure it then — slots_per_bucket, chunk, clock)."""
        if self._scheduler is None:
            from repro.serve.scheduler import ContinuousScheduler
            kw.setdefault("slo", self.slo)
            self._scheduler = ContinuousScheduler(self, **kw)
        elif kw:
            raise ValueError(
                "scheduler already created; configure it on first call")
        return self._scheduler

    def submit(self, req: "Request") -> int:
        """Queue a request for continuous batching; returns its rid."""
        return self.scheduler().submit(req)

    def step(self):
        """One scheduling tick: admit, decode one chunk per geometry
        bucket, retire.  Returns the requests finished this tick."""
        return self.scheduler().tick()

    def drain(self):
        """Tick until every submitted request finished.  Returns a
        ``DrainResult``: the usual {rid: FinishedRequest} mapping plus
        a ``.summary`` with the TTFT split (queue vs prefill), prefix
        hit accounting, per-status counts/rates, and the
        KV/prefix-store occupancy split."""
        finished = self.scheduler().drain()
        return DrainResult(finished, self._drain_summary(finished))

    def cancel(self, rid: int) -> bool:
        """Cooperatively cancel a continuous-batching request (status
        ``cancelled``, partial tokens kept).  False when unknown,
        already finished, or no scheduler exists yet."""
        if self._scheduler is None:
            return False
        return self._scheduler.cancel(rid)

    def inject_fault(self, rid: int) -> None:
        """Chaos-engineering hook: poison request ``rid``'s resident
        decode slot with NaNs (``SlotPool.poison_slot``).  The next
        tick's non-finite sentinel retires exactly that request with
        status ``failed`` and returns the slot to the pool; sibling
        slots continue bitwise-identically (every decode op is
        row-independent).  Raises ``ValueError`` when ``rid`` is not
        resident — the hook corrupts live state, so the request must
        hold a slot (tick until admitted)."""
        if self._scheduler is None:
            raise ValueError(
                "inject_fault: no continuous scheduler exists — submit "
                "and tick the request into a decode slot first")
        self._scheduler.inject_fault(rid)

    def _drain_summary(self, finished) -> Dict[str, Any]:
        ms = [f.metrics for f in finished.values()]
        sched = self._scheduler
        pools = list(sched.pools.values()) if sched is not None else []
        stats = kv_cache_stats([p.caches for p in pools],
                               self.prefix_store)
        prompt_tokens = sum(m.prompt_len for m in ms)
        hit_tokens = sum(m.prefix_hit_tokens for m in ms)
        fid = [m.fidelity for m in ms
               if getattr(m, "fidelity", None) is not None]
        n = len(ms)
        # requests retired without a first token carry ttft = NaN —
        # percentiles are over the requests that actually served
        status_counts = Counter(f.status for f in finished.values())

        def p50(xs: List[float]) -> float:
            xs = [x for x in xs if np.isfinite(x)]
            return float(np.median(xs)) if xs else float("nan")

        return {
            "n_requests": n,
            "status_counts": {s: status_counts.get(s, 0)
                              for s in SLO.STATUSES},
            "shed_rate": (status_counts.get(SLO.STATUS_SHED, 0) / n
                          if n else 0.0),
            "timeout_rate": (status_counts.get(SLO.STATUS_TIMEOUT, 0) / n
                             if n else 0.0),
            "sa_level": self.sa_level,
            "ttft_p50_s": p50([m.ttft for m in ms]),
            "prefill_time_p50_s": p50([m.prefill_time for m in ms]),
            "slot_wait_p50_s": p50([m.slot_wait for m in ms]),
            "prompt_tokens": prompt_tokens,
            "prefix_hit_tokens": hit_tokens,
            "prefix_hit_fraction": (hit_tokens / prompt_tokens
                                    if prompt_tokens else 0.0),
            "kv_payload_bytes": stats.payload_bytes,
            "kv_overhead_bytes": stats.overhead_bytes,
            "prefix_device_bytes": stats.prefix_device_bytes,
            "prefix_host_bytes": stats.prefix_host_bytes,
            "prefix_store": (self.prefix_store.stats()
                             if self.prefix_store is not None else None),
            "decode_kernel": self.decode_kernel_summary(),
            # routing-fidelity probe aggregates (NaN/0 when the probe
            # dial is off — no request carries a fidelity then)
            "fidelity_probed": len(fid),
            "fidelity_p50": p50(fid),
            "fidelity_min": (min(fid) if fid else float("nan")),
        }


# ---------------------------------------------------------------------------
# Request frontends: batch-synchronous and continuous (streaming)
# ---------------------------------------------------------------------------

@dataclass
class Request:
    rid: int
    tokens: np.ndarray  # (S,)
    n_steps: int        # max new tokens
    eos_id: Optional[int] = None   # stop early on this token
    # higher preempts lower when continuous-batching pools fill;
    # meaningless under serve_batch (no slot contention there)
    priority: int = 0
    routing_override: Optional[Tuple[Any, ...]] = None
    # opt this request out of shared-prefix snapshot reuse — neither
    # seeded from nor published to the engine's prefix store (e.g.
    # privacy-scoped prompts that must not warm other tenants)
    prefix_reuse: bool = True
    # TTFT/total budget in seconds from submission (None = the
    # engine's ``slo.default_deadline_s``, which itself defaults to
    # none).  Expired requests retire with status ``timeout`` at the
    # next tick boundary, whether queued, mid-prefill, or mid-decode.
    deadline_s: Optional[float] = None


def _trim_eos(tokens: np.ndarray, eos_id: Optional[int]) -> np.ndarray:
    """Cut a generated stream after the first EOS (inclusive)."""
    if eos_id is None:
        return tokens
    hits = np.flatnonzero(tokens == eos_id)
    return tokens[:hits[0] + 1] if hits.size else tokens


def serve_batch_finished(engine: ServeEngine, requests: Sequence[Request],
                         clock: Callable[[], float] = time.monotonic
                         ) -> Dict[int, "FinishedRequest"]:
    """``serve_batch`` with the continuous frontend's status lifecycle:
    every request returns as a ``FinishedRequest`` whose ``status`` is
    ``ok`` or ``timeout``, so both frontends speak the same vocabulary.

    Deadlines count from the call (the batch frontend has no per-request
    arrival).  Buckets run whole: a request whose deadline expires
    before its bucket starts retires ``timeout`` with no tokens; one
    that expires while its bucket decodes keeps its tokens but is still
    marked ``timeout`` — the batch frontend cannot stop a fused scan
    mid-flight, it can only report the SLO miss honestly.  Shedding,
    preemption and fault quarantine are scheduler concepts and do not
    apply here.
    """
    from repro.serve.scheduler import FinishedRequest, RequestMetrics
    t0 = clock()

    def _deadline(r: Request) -> Optional[float]:
        d = (r.deadline_s if r.deadline_s is not None
             else engine.slo.default_deadline_s)
        if d is not None and d <= 0:
            raise ValueError(
                f"request {r.rid}: deadline_s={d} must be positive — a "
                f"non-positive deadline is expired at submission")
        return None if d is None else t0 + d

    buckets: Dict[Tuple, List[Request]] = {}
    for r in requests:
        buckets.setdefault((len(r.tokens), r.n_steps, r.routing_override,
                            r.prefix_reuse), []).append(r)
    results: Dict[int, FinishedRequest] = {}

    def _finish(r: Request, tokens: np.ndarray, status: str,
                now: float) -> None:
        m = RequestMetrics(prompt_len=len(r.tokens),
                           n_generated=len(tokens), arrival_t=t0,
                           finish_t=now)
        if len(tokens):
            m.admitted_t = t0
        results[r.rid] = FinishedRequest(
            rid=r.rid, tokens=np.asarray(tokens, np.int64),
            routing=None, metrics=m, status=status)

    for (_, n_steps, override, reuse), rs in buckets.items():
        now = clock()
        live = []
        for r in rs:
            dl = _deadline(r)
            if dl is not None and now >= dl:
                _finish(r, np.asarray([], np.int64), SLO.STATUS_TIMEOUT,
                        now)
            else:
                live.append(r)
        if not live:
            continue
        toks = np.stack([r.tokens for r in live])
        gen = engine.generate(toks, n_steps, routing_override=override,
                              prefix_reuse=reuse)
        now = clock()
        for i, r in enumerate(live):
            dl = _deadline(r)
            status = (SLO.STATUS_TIMEOUT if dl is not None and now >= dl
                      else SLO.STATUS_OK)
            _finish(r, _trim_eos(gen.tokens[i], r.eos_id), status, now)
            results[r.rid].routing = gen.routing
    return results


def serve_batch(engine: ServeEngine, requests: Sequence[Request]
                ) -> Dict[int, np.ndarray]:
    """Bucket requests by (length, n_steps, routing_override) and serve
    each bucket batched.  ``eos_id`` trims each stream host-side (the
    fused scan still decodes all n_steps — early exit is what the
    continuous frontend is for), so both frontends return the same
    tokens for the same Request.

    Layer routing is per-bucket (batch-consensus inside the model); the
    paper evaluates per-request routing at B=1 — buckets of size 1
    reproduce that exactly.

    Token-only view of ``serve_batch_finished`` — statuses (and any
    deadline expiries) are dropped; callers that care about the SLO
    lifecycle should use the finished variant directly.
    """
    return {rid: f.tokens
            for rid, f in serve_batch_finished(engine, requests).items()}
