"""Decode-time KV caches.

The paper's sparse-decode (§3.3) keeps, per layer, either the complete
KV (retrieval/FA layers) or only the minimal sink+local buffer (SA
layers).  On TPU this distinction must be *structural*: XLA needs
static shapes, so the SA layers get a fixed-size ring buffer whose
shape (sink+local) is independent of context length — the bandwidth
and memory saving shows up in the compiled artifact, not in a runtime
branch (DESIGN.md §2).

All cache types are registered pytrees so they flow through jit.
Keys are stored with RoPE already applied at absolute positions.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.tree_util import register_dataclass

from repro.configs.base import FluxConfig, ModelConfig


@register_dataclass
@dataclass
class FullKV:
    """Complete KV history, appended at ``length``."""
    k: jax.Array  # (B, Hkv, Smax, D)
    v: jax.Array  # (B, Hkv, Smax, D)
    length: jax.Array  # (B,) int32 — tokens currently valid, per slot


@register_dataclass
@dataclass
class RingKV:
    """Sink + local ring buffer (StreamingLLM geometry).

    Slots [0, sink) hold the attention-sink tokens; slots
    [sink, sink+local) are a ring over the most recent ``local``
    positions.  ``positions`` records each buffer slot's absolute
    position (-1 = empty), **per batch row**: rows are independent
    sequences, so a continuous-batching slot pool can hold requests of
    different lengths in one buffer (DESIGN.md §Scheduler).
    """
    k: jax.Array  # (B, Hkv, sink+local, D)
    v: jax.Array
    positions: jax.Array  # (B, sink+local) int32
    length: jax.Array  # (B,) int32 — absolute position of next token


@register_dataclass
@dataclass
class LatentKV:
    """MLA: compressed latent + shared roped key (full history)."""
    ckv: jax.Array  # (B, Smax, R)
    kr: jax.Array   # (B, 1, Smax, rope_dim)
    length: jax.Array  # (B,) int32


@register_dataclass
@dataclass
class RingLatentKV:
    ckv: jax.Array  # (B, ring, R)
    kr: jax.Array   # (B, 1, ring, rope_dim)
    positions: jax.Array  # (B, ring) int32
    length: jax.Array  # (B,) int32


@register_dataclass
@dataclass
class CrossKV:
    """Whisper decoder cross-attention KV (static, from the encoder)."""
    k: jax.Array  # (B, Hkv, enc_ctx, D)
    v: jax.Array


@register_dataclass
@dataclass
class MambaCache:
    h: jax.Array          # (B, H, P, N) f32 SSD state
    conv_tail: jax.Array  # (B, W-1, conv_channels)


def ring_slot(pos: jax.Array, sink: int, local: int) -> jax.Array:
    """Absolute position → ring slot (elementwise; pos () or (B,))."""
    return jnp.where(pos < sink, pos, sink + (pos - sink) % local)


def _lengths(cache, pos: jax.Array) -> jax.Array:
    """Per-slot next-token positions after inserting at ``pos``.

    ``pos`` is () — all rows at the same position (the single-request
    engine path) — or (B,) per-slot.  The stored ``length`` keeps its
    (B,) shape either way so the cache pytree is a stable scan carry.
    """
    return jnp.broadcast_to(pos + 1, cache.length.shape).astype(
        cache.length.dtype)


# The ring geometry (sink, local) is static config — threaded explicitly.

def ring_insert(cache: RingKV, k_new: jax.Array, v_new: jax.Array,
                pos: jax.Array, sink: int, local: int) -> RingKV:
    slot = ring_slot(pos, sink, local)
    if jnp.ndim(pos) == 0:  # uniform: one slice update covers all rows
        k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, slot, axis=2)
        v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, slot, axis=2)
        positions = cache.positions.at[:, slot].set(pos)
    else:  # per-slot: every row writes its own ring slot (scatter)
        b = jnp.arange(k_new.shape[0])
        k = cache.k.at[b, :, slot].set(k_new[:, :, 0])
        v = cache.v.at[b, :, slot].set(v_new[:, :, 0])
        positions = cache.positions.at[b, slot].set(pos)
    return RingKV(k=k, v=v, positions=positions,
                  length=_lengths(cache, pos))


def ring_latent_insert(cache: RingLatentKV, ckv_new: jax.Array,
                       kr_new: jax.Array, pos: jax.Array, sink: int,
                       local: int) -> RingLatentKV:
    slot = ring_slot(pos, sink, local)
    if jnp.ndim(pos) == 0:
        ckv = jax.lax.dynamic_update_slice_in_dim(cache.ckv, ckv_new, slot,
                                                  axis=1)
        kr = jax.lax.dynamic_update_slice_in_dim(cache.kr, kr_new, slot,
                                                 axis=2)
        positions = cache.positions.at[:, slot].set(pos)
    else:
        b = jnp.arange(ckv_new.shape[0])
        ckv = cache.ckv.at[b, slot].set(ckv_new[:, 0])
        kr = cache.kr.at[b, :, slot].set(kr_new[:, :, 0])
        positions = cache.positions.at[b, slot].set(pos)
    return RingLatentKV(ckv=ckv, kr=kr, positions=positions,
                        length=_lengths(cache, pos))


def full_insert(cache: FullKV, k_new: jax.Array, v_new: jax.Array,
                pos: jax.Array) -> FullKV:
    if jnp.ndim(pos) == 0:
        k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, pos, axis=2)
        v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, pos, axis=2)
    else:
        b = jnp.arange(k_new.shape[0])
        k = cache.k.at[b, :, pos].set(k_new[:, :, 0])
        v = cache.v.at[b, :, pos].set(v_new[:, :, 0])
    return FullKV(k=k, v=v, length=_lengths(cache, pos))


def latent_insert(cache: LatentKV, ckv_new: jax.Array, kr_new: jax.Array,
                  pos: jax.Array) -> LatentKV:
    if jnp.ndim(pos) == 0:
        ckv = jax.lax.dynamic_update_slice_in_dim(cache.ckv, ckv_new, pos,
                                                  axis=1)
        kr = jax.lax.dynamic_update_slice_in_dim(cache.kr, kr_new, pos,
                                                 axis=2)
    else:
        b = jnp.arange(ckv_new.shape[0])
        ckv = cache.ckv.at[b, pos].set(ckv_new[:, 0])
        kr = cache.kr.at[b, :, pos].set(kr_new[:, :, 0])
    return LatentKV(ckv=ckv, kr=kr, length=_lengths(cache, pos))


# ---------------------------------------------------------------------------
# Multi-token (chunk) inserts — the chunked cache-resident prefill path
# (DESIGN.md §Prefill pipeline) appends a whole prompt chunk per call.
# ``start`` is a traced scalar (chunks at different offsets share one
# executable); the chunk length C is static (bucketed by the engine).
# ---------------------------------------------------------------------------

def _ring_chunk_sources(start: jax.Array, C: int, sink: int, local: int
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Ring occupancy after inserting positions [start, start+C).

    Computes, per buffer slot, the *latest* inserted position that lands
    in it (a chunk longer than ``local`` wraps: earlier chunk tokens are
    evicted by later ones within the same insert).  Returns
    (src (ring,), pos (ring,), valid (ring,)): the chunk index to gather
    from, the absolute position it carries, and whether the slot is
    written at all (False = keep the old occupant).
    """
    ring = sink + local
    s = jnp.arange(ring)
    e = start + C - 1  # last inserted position
    # sink slots hold position == slot, written iff start <= s <= e
    sink_valid = (s < sink) & (s >= start) & (s <= e)
    # local slot s holds the largest p <= e with p ≡ s-sink (mod local),
    # provided that p is inside the chunk and past the sink region
    r = s - sink
    q = e - sink
    p = sink + q - jnp.mod(q - r, local)
    loc_valid = (s >= sink) & (e >= sink) & (p >= start) & (p >= sink)
    src = jnp.where(s < sink, s, p) - start
    pos = jnp.where(s < sink, s, p)
    valid = jnp.where(s < sink, sink_valid, loc_valid)
    return src, pos.astype(jnp.int32), valid


def _ring_chunk_positions(cache_positions: jax.Array, pos: jax.Array,
                          valid: jax.Array) -> jax.Array:
    return jnp.where(valid[None, :], pos[None, :], cache_positions)


def ring_insert_chunk(cache: RingKV, k_new: jax.Array, v_new: jax.Array,
                      start: jax.Array, sink: int, local: int) -> RingKV:
    """Insert C tokens (uniform across rows) at [start, start+C)."""
    C = k_new.shape[2]
    src, pos, valid = _ring_chunk_sources(start, C, sink, local)
    idx = jnp.clip(src, 0, C - 1)
    m = valid[None, None, :, None]
    k = jnp.where(m, jnp.take(k_new, idx, axis=2), cache.k)
    v = jnp.where(m, jnp.take(v_new, idx, axis=2), cache.v)
    return RingKV(
        k=k, v=v,
        positions=_ring_chunk_positions(cache.positions, pos, valid),
        length=_lengths(cache, start + C - 1))


def ring_latent_insert_chunk(cache: RingLatentKV, ckv_new: jax.Array,
                             kr_new: jax.Array, start: jax.Array,
                             sink: int, local: int) -> RingLatentKV:
    C = ckv_new.shape[1]
    src, pos, valid = _ring_chunk_sources(start, C, sink, local)
    idx = jnp.clip(src, 0, C - 1)
    ckv = jnp.where(valid[None, :, None],
                    jnp.take(ckv_new, idx, axis=1), cache.ckv)
    kr = jnp.where(valid[None, None, :, None],
                   jnp.take(kr_new, idx, axis=2), cache.kr)
    return RingLatentKV(
        ckv=ckv, kr=kr,
        positions=_ring_chunk_positions(cache.positions, pos, valid),
        length=_lengths(cache, start + C - 1))


def full_insert_chunk(cache: FullKV, k_new: jax.Array, v_new: jax.Array,
                      start: jax.Array) -> FullKV:
    C = k_new.shape[2]
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, start, axis=2)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, start, axis=2)
    return FullKV(k=k, v=v, length=_lengths(cache, start + C - 1))


def latent_insert_chunk(cache: LatentKV, ckv_new: jax.Array,
                        kr_new: jax.Array, start: jax.Array) -> LatentKV:
    C = ckv_new.shape[1]
    ckv = jax.lax.dynamic_update_slice_in_dim(cache.ckv, ckv_new, start,
                                              axis=1)
    kr = jax.lax.dynamic_update_slice_in_dim(cache.kr, kr_new, start,
                                             axis=2)
    return LatentKV(ckv=ckv, kr=kr, length=_lengths(cache, start + C - 1))


# ---------------------------------------------------------------------------
# Construction
# ---------------------------------------------------------------------------

def cache_geometry(caches) -> Tuple:
    """Hashable per-layer geometry signature of a decode-cache list.

    Two routing patterns compile to the same decode executable iff
    their signatures match — the signature is exactly the static axis
    of the jitted decode step (cache pytree structure + buffer
    shapes/dtypes), which is what the engine's executable-count guard
    keys on (DESIGN.md §Serving).
    """
    sig = []
    for c in caches:
        leaves = jax.tree.leaves(c)
        sig.append((type(c).__name__,)
                   + tuple((tuple(a.shape), str(a.dtype)) for a in leaves))
    return tuple(sig)


def slot_geometry(caches) -> Tuple:
    """``cache_geometry`` with the leading batch/slot axis stripped.

    The admission scheduler keys its geometry buckets on this: a B=1
    repacked request and a capacity-C slot pool holding it have the
    same slot geometry, differing only in how many slots ride the
    leading axis (DESIGN.md §Scheduler)."""
    sig = []
    for c in caches:
        leaves = jax.tree.leaves(c)
        sig.append((type(c).__name__,)
                   + tuple((tuple(a.shape[1:]), str(a.dtype))
                           for a in leaves))
    return tuple(sig)


# Bookkeeping fields — device-resident but not KV payload.  Excluded
# from the paper's KV-reduction accounting (kv_cache_bytes).
OVERHEAD_FIELDS = frozenset({"positions", "length"})


def ring_size(flux: FluxConfig) -> int:
    return flux.sink + flux.local


def sa_ring(flux: FluxConfig, max_len: int) -> Tuple[int, int]:
    """(ring, sink) geometry of an SA decode cache under a ``max_len``
    capacity cap.  The ring must keep at least one local slot beyond
    the sink or decode's ``pos % local`` ring arithmetic degenerates
    to a modulo-by-zero."""
    ring = min(ring_size(flux), max_len)
    if ring <= flux.sink:
        raise ValueError(
            f"max_len={max_len} leaves no local slots beyond the "
            f"sink ({flux.sink}); raise max_len or shrink flux.sink")
    return ring, flux.sink


def init_layer_cache(cfg: ModelConfig, kind: str, mode: str, batch: int,
                     max_len: int, dtype=None):
    """Fresh (empty) cache for one layer.

    kind ∈ layer kinds; mode ∈ {"fa", "sa", "local", None}.
    """
    if max_len <= 0:
        raise ValueError(
            f"init_layer_cache: max_len={max_len} must be positive — "
            f"a non-positive capacity would allocate empty (or XLA-"
            f"rejected negative) cache buffers")
    dtype = dtype or cfg.dtype
    flux = cfg.flux
    if kind == "mamba":
        return MambaCache(
            h=jnp.zeros((batch, cfg.ssm_num_heads, cfg.ssm_head_dim,
                         cfg.ssm_state_dim), jnp.float32),
            conv_tail=jnp.zeros(
                (batch, cfg.ssm_conv_width - 1,
                 cfg.ssm_inner + 2 * cfg.ssm_state_dim), dtype))
    if kind == "local":
        L = min(cfg.sliding_window, max_len)
        # pure ring (no sink): reuse RingKV with sink=0
        return RingKV(
            k=jnp.zeros((batch, cfg.num_kv_heads, L, cfg.head_dim), dtype),
            v=jnp.zeros((batch, cfg.num_kv_heads, L, cfg.head_dim), dtype),
            positions=jnp.full((batch, L), -1, jnp.int32),
            length=jnp.zeros((batch,), jnp.int32))
    # attn layer
    if cfg.use_mla:
        if mode == "sa":
            L, _ = sa_ring(flux, max_len)
            return RingLatentKV(
                ckv=jnp.zeros((batch, L, cfg.kv_lora_rank), dtype),
                kr=jnp.zeros((batch, 1, L, cfg.qk_rope_head_dim), dtype),
                positions=jnp.full((batch, L), -1, jnp.int32),
                length=jnp.zeros((batch,), jnp.int32))
        return LatentKV(
            ckv=jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            kr=jnp.zeros((batch, 1, max_len, cfg.qk_rope_head_dim), dtype),
            length=jnp.zeros((batch,), jnp.int32))
    if mode == "sa":
        L, _ = sa_ring(flux, max_len)
        return RingKV(
            k=jnp.zeros((batch, cfg.num_kv_heads, L, cfg.head_dim), dtype),
            v=jnp.zeros((batch, cfg.num_kv_heads, L, cfg.head_dim), dtype),
            positions=jnp.full((batch, L), -1, jnp.int32),
            length=jnp.zeros((batch,), jnp.int32))
    return FullKV(
        k=jnp.zeros((batch, cfg.num_kv_heads, max_len, cfg.head_dim), dtype),
        v=jnp.zeros((batch, cfg.num_kv_heads, max_len, cfg.head_dim), dtype),
        length=jnp.zeros((batch,), jnp.int32))


def init_decode_caches(cfg: ModelConfig, routing: Tuple[str, ...],
                       batch: int, max_len: int):
    """Per-layer cache list for a *static* routing pattern.

    routing[i] ∈ {"fa", "sa"} for routed attn layers; non-attn layers
    derive their cache from the layer kind.
    """
    caches = []
    for i, kind in enumerate(cfg.layer_kinds):
        mode = routing[i] if kind == "attn" else None
        caches.append(init_layer_cache(cfg, kind, mode, batch, max_len))
    return caches
