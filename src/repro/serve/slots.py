"""Slot-pool decode state for continuous batching (DESIGN.md §Scheduler).

A ``SlotPool`` is the device half of the continuous-batching scheduler:
one batched decode-cache list whose leading axis is *slots*, plus the
per-slot last logits and per-slot absolute positions.  Requests join by
having their B=1 repacked prefill caches written into a free slot row
(``write``) and leave by simply being marked free — the row's stale
state is overwritten by the next admission, and free rows decode
garbage that nobody reads (their masks are self-consistent, so they
cannot NaN the batch).

Every pool holds exactly ONE cache geometry (the per-layer
FullKV/RingKV/... buffer shapes dictated by the routing pattern): the
whole point of geometry-bucketed admission is that one compiled
``decode_many`` executable serves the pool forever, preserving the
engine's O(#geometries) executable guarantee while requests of
different lengths churn through the slots (per-slot ``positions``/
``length``/RoPE keep shapes static — kv_cache.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed import pool_sharding as PSH
from repro.serve import kv_cache as KC


@partial(jax.jit, donate_argnums=(0, 1, 2))
def _write_slot(pool_caches, pool_logits, pool_pos, one_caches, one_logits,
                pos, slot):
    """Write a B=1 repacked request into slot row ``slot`` (traced, so
    one executable per pool geometry serves every admission)."""
    caches = jax.tree.map(lambda pool, one: pool.at[slot].set(one[0]),
                          pool_caches, one_caches)
    logits = pool_logits.at[slot].set(one_logits[0])
    return caches, logits, pool_pos.at[slot].set(pos)


@dataclass
class SlotPool:
    """Fixed-capacity batched decode state for one cache geometry."""

    caches: List[Any]        # per-layer cache pytrees, leading axis = slots
    logits: jax.Array        # (capacity, V) last logits per slot
    pos: jax.Array           # (capacity,) int32 next absolute position
    pattern: Tuple[Any, ...]  # representative routing pattern
    capacity: int
    free: List[int] = field(default_factory=list)
    active: Dict[int, Any] = field(default_factory=dict)  # slot → host state
    patterns_served: Set[Tuple[Any, ...]] = field(default_factory=set)
    # Byte accounting for the memory ledger (telemetry.PoolLedgerEntry):
    # computed once at create() from static shapes — pure arithmetic, no
    # device reads.  ``slot_*`` are per-slot-row; ``aux_bytes`` covers
    # the pool's logits/pos buffers that kv_cache_stats never sees.
    slot_payload_bytes: int = 0
    slot_overhead_bytes: int = 0
    aux_bytes: int = 0
    # Tensor-parallel serving (DESIGN.md §Distributed serving): when a
    # mesh is set, the cache k/v buffers are committed head-sharded on
    # the "model" axis and logits/pos replicated; None keeps today's
    # uncommitted single-device arrays bitwise unchanged.
    mesh: Optional[Any] = None

    @classmethod
    def create(cls, cfg: ModelConfig, pattern, capacity: int, max_len: int,
               logits_like: jax.Array, mesh=None) -> "SlotPool":
        # Function-level import: engine imports nothing from slots, so
        # this cannot cycle — and it keeps the byte split definition in
        # exactly one place (kv_cache_stats).
        from repro.serve.engine import kv_cache_stats

        caches = KC.init_decode_caches(cfg, pattern, capacity, max_len)
        logits = jnp.zeros((capacity,) + logits_like.shape[1:],
                           logits_like.dtype)
        pos = jnp.zeros((capacity,), jnp.int32)
        if mesh is not None:
            caches = PSH.shard_pool_caches(caches, mesh)
            logits = PSH.replicate(logits, mesh)
            pos = PSH.replicate(pos, mesh)
        stats = kv_cache_stats(caches)
        # Every leaf's leading axis is ``capacity``, so the division is
        # exact — ledger slot bytes reconcile with kv_cache_stats to the
        # byte regardless of occupancy.  Byte figures stay *global*
        # (logical) bytes under a mesh: the ledger reconciles against
        # kv_cache_stats' global walk either way.
        return cls(
            caches=caches, logits=logits, pos=pos,
            pattern=pattern, capacity=capacity, mesh=mesh,
            free=list(range(capacity - 1, -1, -1)),  # pop() → slot 0 first
            slot_payload_bytes=stats.payload_bytes // capacity,
            slot_overhead_bytes=stats.overhead_bytes // capacity,
            aux_bytes=(logits.size * logits.dtype.itemsize
                       + pos.size * pos.dtype.itemsize))

    def geometry(self) -> Tuple:
        return KC.cache_geometry(self.caches)

    def occupancy(self) -> int:
        """Resident slots — the per-pool batch size telemetry records."""
        return len(self.active)

    def slot_geometry(self) -> Tuple:
        return KC.slot_geometry(self.caches)

    def write(self, slot: int, req_caches, req_logits: jax.Array,
              seq_len: int) -> None:
        """Admit a B=1 repacked request into ``slot``."""
        if KC.slot_geometry(req_caches) != self.slot_geometry():
            raise ValueError(
                "slot-pool geometry mismatch: admission must bucket "
                "requests by cache geometry before packing them")
        if self.mesh is not None:
            # normalize the admission's state to the pool's committed
            # shardings so ``_write_slot`` sees exactly one input
            # sharding per geometry (restore-path and fresh-prefill
            # admissions would otherwise split its jit entries)
            req_caches = PSH.shard_pool_caches(req_caches, self.mesh)
            req_logits = PSH.replicate(req_logits, self.mesh)
        self.caches, self.logits, self.pos = _write_slot(
            self.caches, self.logits, self.pos, req_caches, req_logits,
            jnp.int32(seq_len), jnp.int32(slot))
        if self.mesh is not None:
            # re-commit the jit outputs: ``_write_slot`` is a producer
            # boundary, and its compiler-chosen output shardings would
            # otherwise leak into the next decode's input signature and
            # split the per-(geometry, mesh) executable (guard-fatal)
            self.caches = PSH.shard_pool_caches(self.caches, self.mesh)
            self.logits = PSH.replicate(self.logits, self.mesh)
            self.pos = PSH.replicate(self.pos, self.mesh)

    def poison_slot(self, slot: int) -> None:
        """Chaos-engineering hook: overwrite row ``slot`` of every
        floating-point cache leaf (and its last-logits row) with NaN —
        the persistent-corruption shape of a real fault (a poisoned KV
        row keeps producing non-finite logits every step, so the
        scheduler's per-tick sentinel is guaranteed to see it).
        Integer bookkeeping (``positions``/``length``) is left intact:
        the faulted row must keep decoding self-consistent garbage so
        sibling rows see the exact same shapes and masks as in an
        unfaulted run.  The next admission's ``_write_slot`` overwrites
        the whole row, so a quarantined slot is safe to reuse."""
        def nanify(leaf):
            if jnp.issubdtype(leaf.dtype, jnp.floating):
                return leaf.at[slot].set(jnp.nan)
            return leaf
        self.caches = jax.tree.map(nanify, self.caches)
        self.logits = self.logits.at[slot].set(jnp.nan)

    def advance(self, steps: int) -> None:
        """Advance active rows by ``steps`` decode positions; park free
        rows at 0 so their garbage decode never runs past the buffers."""
        mask = np.zeros((self.capacity,), bool)
        if self.active:
            mask[list(self.active)] = True
        self.pos = jnp.where(jnp.asarray(mask), self.pos + steps, 0)
