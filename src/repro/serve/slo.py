"""SLO guardrails and graceful degradation (DESIGN.md §Robustness & SLO).

Production traffic does not degrade politely: queues grow without
bound, a preemption storm can recompute one victim forever, and a
single non-finite logit row turns a pooled decode batch into silent
garbage.  This module gives the serving stack an explicit failure
vocabulary and a *degradation ladder* instead of a cliff:

  shed      — the waiting queue is bounded (``max_queue``); overflow is
              rejected at submission per ``shed_policy`` instead of
              accumulating unserveable work.
  expire    — every request may carry a ``deadline_s``; expired work is
              retired cooperatively (at tick boundaries) with status
              ``timeout`` whether it is queued, mid-prefill, or
              mid-decode.
  preempt   — recompute-preemption is budgeted (``preemption_budget``):
              a victim evicted that many times becomes non-evictable,
              so it ends in admission, never in livelock.  Aging
              (``aging_s``) raises the *admission* priority of old
              waiters so starvation is bounded too.
  sparsify  — under sustained queue pressure the scheduler turns the
              Layer Router's FA-decision threshold toward SA through a
              quantized ladder (``LoadTracker`` → ``engine.sa_level``),
              trading a little quality for admission throughput, and
              relaxes it when the queue drains.  Levels are clamped to
              the ladder so routing still lands on the existing cache
              geometries and the O(#geometries) executable guard holds.
  quarantine— the scheduler checks decode logits for non-finite rows
              every tick and retires ONLY the poisoned slot (status
              ``failed``); sibling slots are untouched — every decode
              op is row-independent, so their streams stay bitwise
              identical to an unfaulted run (chaos-tested via
              ``engine.inject_fault``).

Every request retires exactly once, as a ``FinishedRequest`` whose
``status`` is one of ``STATUSES`` below; ``ok`` is the only status in
an unstressed system and the only one guaranteed to carry all
``n_steps`` (or EOS-trimmed) tokens.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

# -- request lifecycle statuses ---------------------------------------------
STATUS_OK = "ok"                # finished normally (EOS or n_steps)
STATUS_TIMEOUT = "timeout"      # deadline expired (queued or resident)
STATUS_SHED = "shed"            # rejected by the bounded-queue policy
STATUS_CANCELLED = "cancelled"  # cooperative cancel() by the caller
STATUS_FAILED = "failed"        # quarantined: non-finite decode state

STATUSES = (STATUS_OK, STATUS_TIMEOUT, STATUS_SHED, STATUS_CANCELLED,
            STATUS_FAILED)

# -- shed policies ----------------------------------------------------------
SHED_REJECT_NEWEST = "reject_newest"
SHED_DROP_LOWEST = "drop_lowest_priority"
SHED_POLICIES = (SHED_REJECT_NEWEST, SHED_DROP_LOWEST)


@dataclass(frozen=True)
class SLOConfig:
    """Guardrail knobs for ``ContinuousScheduler`` / ``ServeEngine``.

    Every default is "off": a default-constructed ``SLOConfig`` changes
    no behavior, so the bitwise-parity guarantees of the unguarded
    scheduler are untouched unless a knob is turned.

    ``max_queue``           bound on the waiting queue; ``None`` = unbounded.
    ``shed_policy``         who is rejected when the queue is full:
                            ``reject_newest`` sheds the arrival;
                            ``drop_lowest_priority`` sheds the
                            lowest-priority waiter iff the arrival
                            outranks it (ties shed the arrival).
    ``default_deadline_s``  deadline applied to requests that carry none.
    ``preemption_budget``   max recompute-preemptions per request; once
                            exhausted the request is non-evictable.
    ``aging_s``             waiting seconds per +1 *admission* priority
                            (anti-starvation; raw priorities still
                            govern preemption, so aging cannot start
                            preemption ping-pong).
    ``adaptive_sparsity``   enable the load → SA-bias dial.
    ``sa_level_max``        top rung of the quantized sparsity ladder.
    ``sa_threshold_step``   FA-threshold increment per rung (level L
                            decides FA only when mean p_fa >
                            0.5 + L·step, clamped below 1).
    ``pressure_high/low``   hysteresis band on the queue-pressure signal
                            (waiting / max_queue, or waiting / total
                            slot capacity when unbounded).
    ``pressure_patience``   consecutive ticks outside the band before a
                            rung change — one noisy tick never flips
                            the dial.
    """
    max_queue: Optional[int] = None
    shed_policy: str = SHED_REJECT_NEWEST
    default_deadline_s: Optional[float] = None
    preemption_budget: Optional[int] = None
    aging_s: Optional[float] = None
    adaptive_sparsity: bool = False
    sa_level_max: int = 3
    sa_threshold_step: float = 0.15
    pressure_high: float = 0.75
    pressure_low: float = 0.25
    pressure_patience: int = 2

    def __post_init__(self):
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(
                f"max_queue={self.max_queue} must be >= 1 (or None for "
                f"unbounded): a zero-capacity queue sheds every request "
                f"before anything can admit")
        if self.shed_policy not in SHED_POLICIES:
            raise ValueError(
                f"shed_policy={self.shed_policy!r}: expected one of "
                f"{SHED_POLICIES}")
        if (self.default_deadline_s is not None
                and self.default_deadline_s <= 0):
            raise ValueError(
                f"default_deadline_s={self.default_deadline_s} must be "
                f"positive (or None): a non-positive deadline expires "
                f"every request at submission")
        if (self.preemption_budget is not None
                and self.preemption_budget < 0):
            raise ValueError(
                f"preemption_budget={self.preemption_budget} must be "
                f">= 0 (0 = never evictable) or None (unbudgeted)")
        if self.aging_s is not None and self.aging_s <= 0:
            raise ValueError(
                f"aging_s={self.aging_s} must be positive (or None to "
                f"disable aging): it divides waiting time")
        if self.sa_level_max < 0:
            raise ValueError(
                f"sa_level_max={self.sa_level_max} must be >= 0")
        if self.sa_threshold_step <= 0:
            raise ValueError(
                f"sa_threshold_step={self.sa_threshold_step} must be "
                f"positive: a zero step makes every ladder rung the "
                f"neutral threshold and the dial a no-op")
        if not (0.0 <= self.pressure_low < self.pressure_high <= 1.0):
            raise ValueError(
                f"pressure band must satisfy 0 <= low < high <= 1, got "
                f"low={self.pressure_low} high={self.pressure_high}")
        if self.pressure_patience < 1:
            raise ValueError(
                f"pressure_patience={self.pressure_patience} must be "
                f">= 1 tick")


class LoadTracker:
    """Queue-pressure signal → quantized sparsity level, with hysteresis.

    Pressure is the waiting-queue depth normalized by ``max_queue``
    (when bounded) or by the total resident slot capacity: a backlog the
    pools cannot absorb is the live "we are not keeping up" signal the
    ROADMAP's load-adaptive item calls for.  Slot *occupancy* is
    deliberately not part of the signal — a full pool with an empty
    queue is a healthy steady state, not overload.

    ``observe`` is called once per scheduler tick; the level moves one
    rung at a time, only after ``pressure_patience`` consecutive ticks
    beyond ``pressure_high`` (up) or at/below ``pressure_low`` (down).
    """

    def __init__(self, slo: SLOConfig):
        self.slo = slo
        self.level = 0
        self.pressure = 0.0
        # lifetime rung changes, either direction — a dial that flaps is
        # a tuning smell, and this is the cheapest signal of it (the
        # telemetry registry exposes it as flux_sa_transitions_total)
        self.transitions = 0
        self._hot = 0
        self._cold = 0

    def observe(self, queue_len: int, capacity: int) -> int:
        slo = self.slo
        denom = slo.max_queue if slo.max_queue else max(capacity, 1)
        self.pressure = min(queue_len / max(denom, 1), 1.0)
        if self.pressure >= slo.pressure_high:
            self._hot, self._cold = self._hot + 1, 0
            if (self._hot >= slo.pressure_patience
                    and self.level < slo.sa_level_max):
                self.level += 1
                self.transitions += 1
                self._hot = 0
        elif self.pressure <= slo.pressure_low:
            self._cold, self._hot = self._cold + 1, 0
            if self._cold >= slo.pressure_patience and self.level > 0:
                self.level -= 1
                self.transitions += 1
                self._cold = 0
        else:
            self._hot = self._cold = 0
        return self.level
