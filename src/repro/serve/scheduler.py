"""Continuous-batching admission/step scheduler (DESIGN.md §Scheduler,
§Robustness & SLO).

The engine's ``generate`` serves one request end-to-end; ``serve_batch``
buckets by exact (length, n_steps) and runs buckets to completion —
mixed-length traffic serializes.  This scheduler instead keeps a
persistent decode batch that requests join and leave per step:

  admit   — stream a waiting request's prompt through the chunked
            cache-resident prefill at B=1 (the Layer Router fires once
            per request, on the first chunk), then pack its decode
            caches into a free slot of the pool matching its *cache
            geometry*.  Prefill chunks are SCHEDULABLE TICK WORK: at
            most ``prefill_chunks_per_tick`` chunks run per tick,
            interleaved with the decode chunks below (Sarathi-style
            mixed ticks), so a long prompt cannot stall the resident
            batch and TTFT of running requests stays fair under load.
            Geometry-bucketed admission is the Flux-specific twist: the
            decode executable is keyed by geometry (PR 1), so mixing
            geometries in one pool would force recompiles — grouping
            by geometry preserves the O(#geometries) guarantee.
            Requests ``chunked_eligible`` excludes (duo overrides,
            non-ssa SA) admit through the monolithic repack fallback.
  step    — per tick, run ONE compiled ``decode_many`` chunk (default
            8 steps) for every pool with active slots: chunked scans,
            not run-to-completion, so new arrivals wait at most one
            chunk before joining.
  retire  — finished slots (EOS / max_new_tokens) are freed; their
            rows are overwritten by the next admission.
  preempt — when a pool is full, an arrival with strictly higher
            priority evicts the lowest-priority slot; the victim is
            re-queued and later re-prefilled over prompt + tokens
            generated so far (recompute preemption — the standard
            trade of prefill FLOPs for pool memory).

On top of that sits the SLO/robustness layer (serve/slo.py): every
request retires exactly once with an explicit ``status`` — ``ok``,
``timeout`` (deadline expired, queued or resident), ``shed`` (bounded
queue rejected it), ``cancelled`` (cooperative ``cancel``), or
``failed`` (non-finite decode state quarantined) — and overload walks
a degradation ladder (shed → budgeted preemption → SA-biased routing)
instead of falling off a cliff.  All guardrails default OFF; a
default ``SLOConfig`` reproduces the unguarded scheduler bit-for-bit.

Decoding is greedy: pooled categorical sampling could not reproduce
the B=1 sampling stream anyway, and greedy pooled decode is *bitwise*
equal to sequential ``generate`` (asserted in tests) because every op
on the decode path is row-independent.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import kv_cache as KC
from repro.serve import slo as SLO
from repro.serve import telemetry as TM
from repro.serve import tracing as TR
from repro.serve.engine import (KVStats, _trim_eos, decode_executable_key,
                                kv_cache_stats)
from repro.serve.slots import SlotPool


@dataclass
class RequestMetrics:
    """Per-request serving metrics (seconds, ``clock`` domain)."""
    prompt_len: int = 0
    n_generated: int = 0
    arrival_t: float = 0.0
    admitted_t: Optional[float] = None   # first admission
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    preemptions: int = 0
    # queue-time vs prefill-time split: [prefill_start_t, prefill_done_t]
    # brackets the chunked prefill of the admission that finally landed
    # (reset on preemption), so a TTFT regression is attributable to
    # waiting vs prefilling.
    prefill_start_t: Optional[float] = None
    prefill_done_t: Optional[float] = None
    # decode-cache footprint at admission (payload/overhead split)
    kv_stats: Optional[KVStats] = None
    # prompt tokens seeded from a warm prefix snapshot (the admission
    # that landed): these tokens issued NO prefill chunks, so a TTFT
    # win is attributable to reuse vs queueing via the prefill split
    prefix_hit_tokens: int = 0
    # routing-fidelity probe results (only for sampled admissions when
    # the engine runs with fidelity_probe_every=N; None = not probed):
    # mean attention-mass coverage across routed layers, and the worst
    # coverage among layers the router sent down the SA path — the
    # number that quantifies what sparse attention actually discarded
    fidelity: Optional[float] = None
    fidelity_sa_min: Optional[float] = None

    @property
    def queue_delay(self) -> float:
        return (self.admitted_t or self.arrival_t) - self.arrival_t

    @property
    def prefill_time(self) -> float:
        """Wall-clock spent streaming this request's prefill chunks."""
        if self.prefill_start_t is None or self.prefill_done_t is None:
            return 0.0
        return self.prefill_done_t - self.prefill_start_t

    @property
    def slot_wait(self) -> float:
        """Queue delay net of prefill: time spent purely waiting (for a
        tick's prefill budget or a free slot)."""
        return max(self.queue_delay - self.prefill_time, 0.0)

    @property
    def ttft(self) -> float:
        """Time to first token, from arrival.  NaN for a request that
        never produced one (shed, cancelled, or expired before its
        first decode chunk) — partial lifecycles must not read as a
        zero-latency first token in drain summaries."""
        if self.first_token_t is None:
            return float("nan")
        return self.first_token_t - self.arrival_t

    @property
    def decode_tps(self) -> float:
        if self.finish_t is None or self.admitted_t is None:
            return float("nan")
        dt = self.finish_t - self.admitted_t
        return self.n_generated / dt if dt > 0 else float("inf")


@dataclass
class FinishedRequest:
    rid: int
    tokens: np.ndarray           # (n_generated,)
    # pattern of the final admission; None when the request retired
    # before ever routing (shed / expired / cancelled in queue)
    routing: Optional[Tuple[Any, ...]]
    metrics: RequestMetrics
    status: str = SLO.STATUS_OK  # one of slo.STATUSES


@dataclass
class _InFlight:
    """Host-side record of a submitted request."""
    req: Any                     # serve.Request
    metrics: RequestMetrics
    generated: List[int] = field(default_factory=list)
    pattern: Optional[Tuple[Any, ...]] = None
    pool_key: Optional[Tuple] = None
    slot: int = -1
    # absolute expiry time in the clock domain (None = no deadline)
    deadline_t: Optional[float] = None
    # in-flight chunked prefill (engine.ChunkedPrefill); advanced by the
    # tick's prefill budget, packed into a slot once done.  A finished
    # job whose bucket is full simply waits — its caches are already
    # decode-geometry, nothing is recomputed.
    job: Optional[Any] = None
    # geometry bucket seen at the last failed MONOLITHIC admission —
    # lets the scheduler skip re-prefilling a fallback request whose
    # bucket is still full (tokens don't change while waiting, so the
    # routing is stable)
    cached_key: Optional[Tuple] = None


class ContinuousScheduler:
    """Slot-pool continuous batching over a ``ServeEngine``.

    ``slots_per_bucket``: capacity of each geometry bucket's pool.
    ``chunk``: decode steps per tick per pool — the scheduling quantum.
    ``prefill_chunks_per_tick``: prefill chunks streamed per tick across
    all in-flight admissions — the prefill scheduling quantum.
    ``clock``: injectable time source (tests pass a virtual clock).
    ``slo``: guardrail config (serve/slo.py); defaults to all-off.
    """

    def __init__(self, engine, *, slots_per_bucket: int = 4,
                 chunk: int = 8, prefill_chunks_per_tick: int = 1,
                 clock: Callable[[], float] = time.monotonic,
                 slo: Optional[SLO.SLOConfig] = None):
        if engine.cfg.num_encoder_layers or engine.cfg.num_prefix_tokens:
            raise ValueError(
                "continuous batching supports decoder-only text requests; "
                "encoder/prefix modalities carry per-request side inputs "
                "the slot pool does not hold yet")
        if slots_per_bucket < 1:
            raise ValueError(
                f"slots_per_bucket={slots_per_bucket} must be >= 1: a "
                f"zero-capacity pool can never admit, so every request "
                f"would wait forever (drain would spin to its progress "
                f"guard instead of serving)")
        if chunk < 1:
            raise ValueError(
                f"chunk={chunk} must be >= 1 decode step per tick: a "
                f"zero-step scan generates no tokens and no request can "
                f"ever finish")
        if prefill_chunks_per_tick < 1:
            raise ValueError(
                f"prefill_chunks_per_tick={prefill_chunks_per_tick} must "
                f"be >= 1: with a zero budget a chunked-eligible request "
                f"can never admit (its prefill job never advances).  To "
                f"disable mixed ticks, build the engine with "
                f"prefill_chunk=None instead")
        self.engine = engine
        self.slots_per_bucket = int(slots_per_bucket)
        self.chunk = int(chunk)
        self.prefill_chunks_per_tick = int(prefill_chunks_per_tick)
        self.clock = clock
        self.slo = slo if slo is not None else SLO.SLOConfig()
        # one source of truth for the sparsity ladder: the engine's
        # dial (generate() + chunked admissions) follows this config
        engine.slo = self.slo
        # register with the engine so ledger_report / attribution_report
        # see this scheduler's pools even when it was constructed
        # directly rather than via engine.scheduler()
        engine._scheduler = self
        self.load = SLO.LoadTracker(self.slo)
        self.waiting: List[_InFlight] = []
        self.pools: Dict[Tuple, SlotPool] = {}
        self.finished: List[FinishedRequest] = []
        self.closed = False           # set by drain(); submit then raises
        self._announce: List[FinishedRequest] = []  # retired since last tick
        self._rng = jax.random.key(0)
        self.ticks = 0
        self.tokens_generated = 0
        self.prefill_chunk_ticks = 0  # prefill chunks streamed, lifetime
        # telemetry bookkeeping (engine.telemetry is None ⇒ never read):
        # non-ok lifecycle events since the last tick, a stable small-int
        # id per geometry bucket for trace/recorder labels, and the
        # LoadTracker.transitions watermark for the delta counter
        self._tm_events: List[str] = []
        self._tm_pool_ids: Dict[Tuple, int] = {}
        self._tm_transitions = 0
        # prefix-cache (hits, misses) watermark and the last sa_level
        # seen, for per-tick deltas / transition events in TickRecord
        self._tm_prefix: Tuple[int, int] = (0, 0)
        self._tm_sa_level = engine.sa_level

    # -- submission --------------------------------------------------------
    def submit(self, req) -> int:
        """Queue a request (``serve.Request``); returns its rid.

        A bounded queue (``slo.max_queue``) may retire the arrival — or
        a lower-priority waiter — immediately with status ``shed``;
        the retirement is announced by the next ``tick`` and appears in
        ``drain`` like any other terminal state.
        """
        if self.closed:
            raise ValueError(
                f"submit after drain: request {req.rid} would queue on a "
                f"drained scheduler that no longer ticks, and would "
                f"silently never be served — create a new scheduler (or "
                f"submit before draining)")
        if len(req.tokens) > self.engine.max_len:
            raise ValueError(
                f"request {req.rid}: prompt length {len(req.tokens)} "
                f"exceeds the engine's cache capacity max_len="
                f"{self.engine.max_len}; raise max_len or truncate the "
                f"prompt")
        need = len(req.tokens) + req.n_steps
        if need > self.engine.max_len:
            raise ValueError(
                f"request {req.rid}: prompt ({len(req.tokens)}) + n_steps "
                f"({req.n_steps}) = {need} exceeds the engine's cache "
                f"capacity max_len={self.engine.max_len}; slot-pool rows "
                f"past capacity would silently drop KV writes (and a "
                f"preemption-recompute would crash mid-drain)")
        deadline = getattr(req, "deadline_s", None)
        if deadline is not None and deadline <= 0:
            raise ValueError(
                f"request {req.rid}: deadline_s={deadline} must be "
                f"positive — a non-positive deadline is expired at "
                f"submission and can never be served")
        if deadline is None:
            deadline = self.slo.default_deadline_s
        now = self.clock()
        inf = _InFlight(req=req, metrics=RequestMetrics(
            prompt_len=len(req.tokens), arrival_t=now),
            deadline_t=(now + deadline) if deadline is not None else None)
        eng = self.engine
        if eng.telemetry is not None:
            eng.telemetry.counter("serve_requests_submitted_total").inc()
        if eng.tracer is not None:
            eng.tracer.name_thread(TR.PID_REQUESTS, req.rid,
                                   f"req{req.rid}", sort_index=req.rid)
            eng.tracer.instant(
                "submit", TR.PID_REQUESTS, req.rid, now,
                args={"prompt_len": len(req.tokens),
                      "n_steps": req.n_steps,
                      "priority": getattr(req, "priority", 0)})
        if (self.slo.max_queue is not None
                and len(self.waiting) >= self.slo.max_queue):
            victim = self._shed_victim(inf)
            if victim is not inf:
                self.waiting.remove(victim)
                self.waiting.append(inf)
            self._retire(victim, SLO.STATUS_SHED, now)
            return req.rid
        self.waiting.append(inf)
        return req.rid

    def _shed_victim(self, inf: _InFlight) -> _InFlight:
        """Pick who the over-bound queue rejects: the arrival itself
        (``reject_newest``) or the lowest-priority waiter when the
        arrival strictly outranks it (``drop_lowest_priority``; ties
        shed the arrival, so equal-priority waiters keep FIFO order)."""
        if self.slo.shed_policy == SLO.SHED_REJECT_NEWEST:
            return inf
        victim = min(self.waiting,
                     key=lambda w: (w.req.priority, -w.metrics.arrival_t))
        return victim if victim.req.priority < inf.req.priority else inf

    # -- terminal transitions ----------------------------------------------
    def _retire(self, inf: _InFlight, status: str, now: float, *,
                pool: Optional[SlotPool] = None,
                slot: Optional[int] = None) -> FinishedRequest:
        """The single terminal transition: every request leaves the
        scheduler through here exactly once, with an explicit status
        and whatever tokens it generated before retiring.  Frees the
        decode slot when the request was resident."""
        m = inf.metrics
        m.finish_t = now
        m.n_generated = len(inf.generated)
        inf.job = None
        if pool is not None and slot is not None:
            pool.active.pop(slot)
            pool.free.append(slot)
            inf.slot, inf.pool_key = -1, None
        f = FinishedRequest(rid=inf.req.rid,
                            tokens=np.asarray(inf.generated, np.int64),
                            routing=inf.pattern, metrics=m, status=status)
        self.finished.append(f)
        self._announce.append(f)
        if self.engine.telemetry is not None:
            self._tm_retire(f, now)
        return f

    def _tm_retire(self, f: FinishedRequest, now: float) -> None:
        """Telemetry for one terminal transition: status counter,
        latency histograms, and the request's lifetime span with its
        queue/prefill/decode phase sub-spans (all from RequestMetrics
        timestamps already taken — no extra clock reads)."""
        eng = self.engine
        m = f.metrics
        if f.status != SLO.STATUS_OK:
            self._tm_events.append(f"{f.status}:{f.rid}")
        reg = eng.telemetry
        reg.counter("serve_requests_finished_total", status=f.status).inc()
        if m.first_token_t is not None:
            reg.histogram("serve_ttft_seconds",
                          "time to first token, from arrival"
                          ).observe(m.ttft)
        if m.admitted_t is not None:
            reg.histogram("serve_queue_delay_seconds",
                          "arrival to (final) admission"
                          ).observe(m.queue_delay)
        if m.prefill_time > 0:
            reg.histogram("serve_prefill_seconds",
                          "wall clock streaming the landed admission's "
                          "prefill chunks").observe(m.prefill_time)
        tracer = eng.tracer
        if tracer is None:
            return
        rid = f.rid
        tracer.name_thread(TR.PID_REQUESTS, rid, f"req{rid}",
                           sort_index=rid)
        span_args = {"status": f.status, "prompt_len": m.prompt_len,
                     "n_generated": m.n_generated,
                     "preemptions": m.preemptions,
                     "prefix_hit_tokens": m.prefix_hit_tokens}
        if m.fidelity is not None:
            span_args["fidelity"] = round(m.fidelity, 6)
            if m.fidelity_sa_min is not None:
                span_args["fidelity_sa_min"] = round(m.fidelity_sa_min, 6)
        tracer.complete(
            f"req{rid}", TR.PID_REQUESTS, rid, m.arrival_t, now,
            args=span_args)
        if m.admitted_t is not None:
            tracer.complete("queue", TR.PID_REQUESTS, rid,
                            m.arrival_t, m.admitted_t, cat="phase")
            tracer.complete("decode", TR.PID_REQUESTS, rid,
                            m.admitted_t, now, cat="phase")
        if m.prefill_start_t is not None and m.prefill_done_t is not None:
            tracer.complete("prefill", TR.PID_REQUESTS, rid,
                            m.prefill_start_t, m.prefill_done_t,
                            cat="phase")
        tracer.instant(f"retire:{f.status}", TR.PID_REQUESTS, rid, now)

    def cancel(self, rid: int) -> bool:
        """Cooperative cancellation: retire ``rid`` with status
        ``cancelled`` (partial tokens kept).  A resident request leaves
        at the current tick boundary — its slot frees immediately and
        is overwritten by the next admission.  Returns False when the
        rid is unknown or already finished."""
        now = self.clock()
        for inf in self.waiting:
            if inf.req.rid == rid:
                self.waiting.remove(inf)
                self._retire(inf, SLO.STATUS_CANCELLED, now)
                return True
        for pool in self.pools.values():
            for slot, inf in list(pool.active.items()):
                if inf.req.rid == rid:
                    self._retire(inf, SLO.STATUS_CANCELLED, now,
                                 pool=pool, slot=slot)
                    return True
        return False

    def inject_fault(self, rid: int) -> None:
        """Chaos hook (see ``ServeEngine.inject_fault``): poison the
        resident decode state of ``rid`` with NaNs.  The next decode
        chunk's non-finite sentinel quarantines exactly that slot
        (status ``failed``); sibling slots must stay bitwise identical
        to an unfaulted run (chaos-tested)."""
        for pool in self.pools.values():
            for slot, inf in pool.active.items():
                if inf.req.rid == rid:
                    pool.poison_slot(slot)
                    return
        raise ValueError(
            f"inject_fault: request {rid} is not resident in any decode "
            f"slot (still waiting, already finished, or unknown) — the "
            f"fault hook poisons live slot state, so admit the request "
            f"first (tick until it holds a slot)")

    def _expire(self, now: float) -> None:
        """Retire everything past its deadline — queued (possibly
        mid-prefill: the in-flight job is simply dropped) and resident
        alike.  Cooperative by construction: expiry is checked at tick
        boundaries, so a resident request finishes its current decode
        chunk and keeps the tokens generated before the boundary."""
        keep = []
        for inf in self.waiting:
            if inf.deadline_t is not None and now >= inf.deadline_t:
                self._retire(inf, SLO.STATUS_TIMEOUT, now)
            else:
                keep.append(inf)
        self.waiting = keep
        for pool in self.pools.values():
            for slot, inf in list(pool.active.items()):
                if inf.deadline_t is not None and now >= inf.deadline_t:
                    self._retire(inf, SLO.STATUS_TIMEOUT, now,
                                 pool=pool, slot=slot)

    # -- priorities: aging + preemption budget ------------------------------
    def _eff_priority(self, inf: _InFlight, now: float) -> float:
        """Admission priority with anti-starvation aging: a waiter gains
        one priority unit per ``aging_s`` seconds, so a much-preempted
        victim eventually outranks fresh arrivals for free slots.
        Aging is deliberately *admission-only* — ``_preempt`` compares
        raw priorities, so two aged requests can never enter a
        mutual-eviction ping-pong."""
        if self.slo.aging_s is None:
            return float(inf.req.priority)
        return (inf.req.priority
                + (now - inf.metrics.arrival_t) / self.slo.aging_s)

    def _evictable(self, inf: _InFlight) -> bool:
        """Preemption budget: once a request has been recompute-preempted
        ``slo.preemption_budget`` times it becomes non-evictable, so a
        preemption storm ends in its admission, not a livelock of
        re-prefills."""
        budget = self.slo.preemption_budget
        return budget is None or inf.metrics.preemptions < budget

    # -- admission ---------------------------------------------------------
    def _prefill_tokens(self, inf: _InFlight) -> np.ndarray:
        """Prompt plus tokens generated before a preemption: recompute
        preemption replays the request's own history through prefill."""
        if not inf.generated:
            return np.asarray(inf.req.tokens)
        return np.concatenate([np.asarray(inf.req.tokens),
                               np.asarray(inf.generated, np.int32)])

    def _has_victim(self, pool: SlotPool, priority: int) -> bool:
        return any(v.req.priority < priority and self._evictable(v)
                   for v in pool.active.values())

    def _prefill_work(self, pending: List[_InFlight]) -> Tuple[int, int]:
        """Stream up to ``prefill_chunks_per_tick`` chunks across the
        waiting requests' admission jobs, priority-then-arrival order —
        prefill is tick work on equal footing with decode chunks.
        Returns (chunks streamed, prompt tokens streamed) so the cost
        profiler can attribute the phase's expressed FLOPs."""
        eng = self.engine
        budget = self.prefill_chunks_per_tick
        chunks = tokens_streamed = 0
        for inf in pending:
            if budget <= 0:
                break
            if inf.job is None:
                tokens = self._prefill_tokens(inf)
                if not eng.chunked_eligible(
                        len(tokens),
                        getattr(inf.req, "routing_override", None)):
                    continue  # monolithic fallback admits in _admit
                inf.job = eng.start_chunked_prefill(
                    jnp.asarray(tokens)[None],
                    getattr(inf.req, "routing_override", None),
                    reuse=getattr(inf.req, "prefix_reuse", True))
                # clamp to the prompt: a preemption-recompute replays
                # prompt+generated, and its hit boundary may cover
                # tokens this request generated itself — those are not
                # "prompt tokens served warm"
                inf.metrics.prefix_hit_tokens = min(
                    inf.job.prefix_hit_tokens, inf.metrics.prompt_len)
                inf.metrics.prefill_start_t = self.clock()
            while budget > 0 and not inf.job.done:
                t0 = self.clock() if eng.tracer is not None else 0.0
                tokens_streamed += inf.job.plan[inf.job.idx][1]
                inf.job.step()
                self.prefill_chunk_ticks += 1
                chunks += 1
                budget -= 1
                if eng.telemetry is not None:
                    eng.telemetry.counter("serve_prefill_chunks_total").inc()
                if eng.tracer is not None:
                    eng.tracer.complete(
                        "prefill_chunk", TR.PID_REQUESTS, inf.req.rid,
                        t0, self.clock(), cat="phase")
            if inf.job.done and inf.metrics.prefill_done_t is None:
                inf.metrics.prefill_done_t = self.clock()
        return chunks, tokens_streamed

    def _admit(self, inf: _InFlight) -> bool:
        eng = self.engine
        if inf.job is not None:
            # chunked admission: pack only once the stream finished
            if not inf.job.done:
                return False
            pattern, caches = inf.job.pattern, inf.job.caches
            logits, seq_len = inf.job.logits, inf.job.seq_len
        elif eng.chunked_eligible(len(self._prefill_tokens(inf)),
                                  getattr(inf.req, "routing_override",
                                          None)):
            # chunked-eligible but this tick's prefill budget ran out
            # before its job started — wait, don't fall back
            return False
        else:
            if inf.cached_key is not None:
                known = self.pools.get(inf.cached_key)
                if (known is not None and not known.free
                        and not self._has_victim(known, inf.req.priority)):
                    return False  # bucket still full — skip the re-prefill
            tokens = self._prefill_tokens(inf)
            pf, pattern, caches, seq_len = eng.prefill_route_repack(
                jnp.asarray(tokens)[None],
                getattr(inf.req, "routing_override", None))
            logits = pf.logits
            eng.dispatch_count += 2  # prefill + the jitted repack
        if any(isinstance(p, tuple) for p in pattern):
            raise ValueError(
                "duo head-split patterns carry traced per-layer state the "
                "slot pool does not thread yet; serve them via generate()")
        key = KC.slot_geometry(caches)
        pool = self.pools.get(key)
        if pool is None:
            pool = SlotPool.create(eng.cfg, pattern, self.slots_per_bucket,
                                   eng.max_len, logits, mesh=eng.mesh)
            if KC.slot_geometry(pool.caches) != key:
                raise AssertionError(
                    "init_decode_caches geometry diverged from "
                    "admission cache geometry for one pattern")
            self.pools[key] = pool
        if pool.free:
            slot = pool.free.pop()
        else:
            slot = self._preempt(pool, inf.req.priority)
            if slot is None:
                inf.cached_key = key
                return False  # bucket full of equal/higher priority work
        now = self.clock()
        if inf.metrics.admitted_t is None:
            inf.metrics.admitted_t = now
        inf.metrics.kv_stats = kv_cache_stats(caches)
        inf.pattern, inf.pool_key, inf.slot = pattern, key, slot
        inf.cached_key = None
        pool.patterns_served.add(pattern)
        pool.write(slot, caches, logits, seq_len)
        pool.active[slot] = inf
        if eng.fidelity_probe_every:
            cov = eng._maybe_fidelity_probe(self._prefill_tokens(inf),
                                            pattern)
            if cov is not None and cov.size:
                inf.metrics.fidelity = float(np.mean(cov))
                sa = [float(cov[j]) for j, i in
                      enumerate(eng.cfg.routable_layers())
                      if j < cov.size and pattern[i] == "sa"]
                if sa:
                    inf.metrics.fidelity_sa_min = min(sa)
        if inf.job is not None:
            eng.dispatch_count += inf.job.dispatches
            inf.job = None
        return True

    def _preempt(self, pool: SlotPool, priority: int) -> Optional[int]:
        """Evict the lowest-priority *evictable* active slot if it is
        strictly below ``priority``; the victim re-queues for recompute
        admission.  Budget-exhausted slots (``_evictable`` False) are
        skipped — they already paid ``slo.preemption_budget`` recompute
        prefills and now run to completion."""
        cands = [(s, v) for s, v in pool.active.items()
                 if self._evictable(v)]
        if not cands:
            return None
        slot, victim = min(
            cands, key=lambda kv: (kv[1].req.priority,
                                   -kv[1].metrics.arrival_t))
        if victim.req.priority >= priority:
            return None
        pool.active.pop(slot)
        victim.metrics.preemptions += 1
        victim.slot, victim.pool_key = -1, None
        victim.cached_key = None  # its tokens grew; routing may change
        victim.job = None         # recompute prefill over prompt+generated
        # re-bracket the prefill split around the admission that lands
        victim.metrics.prefill_start_t = None
        victim.metrics.prefill_done_t = None
        victim.metrics.prefix_hit_tokens = 0
        self.waiting.append(victim)
        eng = self.engine
        if eng.telemetry is not None:
            eng.telemetry.counter("serve_preemptions_total").inc()
            self._tm_events.append(f"preempt:{victim.req.rid}")
        if eng.tracer is not None:
            eng.tracer.instant("preempt", TR.PID_REQUESTS,
                               victim.req.rid, self.clock(),
                               args={"by_priority": priority})
        return slot

    # -- one scheduling tick -----------------------------------------------
    def tick(self) -> List[FinishedRequest]:
        """Expire deadlines, adjust the sparsity dial, stream prefill
        chunks, admit finished admissions, decode one chunk per bucket,
        retire finished slots, quarantine non-finite ones.  Returns
        every request that retired since the last tick (including
        submission-time sheds)."""
        eng = self.engine
        self.ticks += 1
        now = self.clock()
        tm_on = eng.telemetry is not None
        prof = eng.profiler
        # prof_on gates every sync boundary below: unsampled ticks take
        # the exact dispatch/sync sequence of a profiler-off run
        prof_on = prof is not None and prof.should_sample(self.ticks)
        if prof_on:
            prof.note_sampled_tick()
        if tm_on:
            # deltas for this tick's flight record / counters; taking
            # them costs three attribute reads — nothing touches jax
            tm_t0, tm_d0 = now, eng.dispatch_count
            tm_p0, tm_tok0 = self.prefill_chunk_ticks, self.tokens_generated
        self._expire(now)
        if self.slo.adaptive_sparsity:
            cap = sum(p.capacity for p in self.pools.values())
            eng.set_sa_level(self.load.observe(
                len(self.waiting), cap or self.slots_per_bucket))
        # admit in (aged) priority order, oldest first within a
        # priority.  _admit may re-queue preemption victims onto
        # self.waiting, so iterate a snapshot and let victims wait for
        # the next tick.
        pending = sorted(self.waiting,
                         key=lambda i: (-self._eff_priority(i, now),
                                        i.metrics.arrival_t))
        if prof_on:
            # pure host work so far: expiry, the dial, the sort
            prof.record("queue", host_s=self.clock() - now,
                        count=len(pending))
        t_pf = self.clock() if prof_on else 0.0
        pf_chunks, pf_tokens = self._prefill_work(pending)
        if prof_on:
            t_host = self.clock()
            eng.device_sync([inf.job.logits for inf in pending
                            if inf.job is not None])
            n_par, par_bytes = eng._params_cost()
            prof.record("prefill_chunk",
                        host_s=t_host - t_pf,
                        device_s=self.clock() - t_host,
                        flops=2.0 * n_par * pf_tokens,
                        hbm_bytes=float(par_bytes) * pf_chunks,
                        count=pf_chunks)
        t_ad = self.clock() if prof_on else 0.0
        self.waiting = []
        n_admitted = 0
        for inf in pending:
            if self._admit(inf):
                n_admitted += 1
            else:
                self.waiting.append(inf)
        if prof_on:
            t_host = self.clock()
            eng.device_sync([p.logits for p in self.pools.values()])
            prof.record("admit", host_s=t_host - t_ad,
                        device_s=self.clock() - t_host,
                        count=n_admitted)

        for key, pool in self.pools.items():
            if not pool.active:
                continue
            t_decode = self.clock() if tm_on else 0.0
            dk = decode_executable_key(pool.caches, pool.pos, self.chunk,
                                       True, None, None, self._rng,
                                       mesh_sig=eng._mesh_sig)
            eng._decode_keys.add(dk)
            with warnings.catch_warnings(), eng._attn_ctx():
                # install the engine's decode backend for the pooled
                # scan, same trace-time protocol as ``generate``;
                # donation warnings are CPU-backend noise
                warnings.filterwarnings("ignore", message=".*[Dd]onat.*")
                toks, logits, caches = eng._decode_many(
                    params=eng.params, logits=pool.logits,
                    caches=pool.caches, pos=pool.pos, rng=self._rng,
                    n_steps=self.chunk, greedy=True, enc_out=None,
                    fa_heads=None, duo_layers=None,
                    unroll=eng.decode_unroll)
            eng._note_decode_dispatch(dk)
            eng.dispatch_count += 1
            t_disp = self.clock() if prof_on else 0.0
            if eng.mesh is not None:
                # pin the decode outputs back to the pool shardings so
                # the next tick's inputs key the SAME executable even
                # if the compiler chose different output shardings
                # (no-op copy when they already match)
                caches, logits = eng._commit_state(caches, logits)
            pool.logits, pool.caches = logits, caches
            pool.advance(self.chunk)
            toks_np = np.asarray(toks)  # (capacity, chunk)
            # non-finite sentinel: one reduced (capacity,) bool per tick.
            # Fault isolation is slot-granular — a poisoned row retires
            # as ``failed`` (its garbage chunk discarded) while sibling
            # rows proceed untouched; every decode op is row-independent
            # so their streams are bitwise those of an unfaulted run.
            finite = np.asarray(jnp.all(jnp.isfinite(pool.logits), axis=-1))
            now = self.clock()
            if prof_on:
                # host_s = dispatch (trace lookup + call issue); device_s
                # = the wait inside the np.asarray syncs above — no
                # extra sync is inserted, the tick already blocks here
                cost = eng._expressed_decode_cost(pool, dk, self.chunk)
                prof.record("decode", host_s=t_disp - t_decode,
                            device_s=now - t_disp,
                            flops=cost["flops"],
                            hbm_bytes=cost["hbm_bytes"],
                            count=self.chunk * len(pool.active))
                for ph in ("kernel_hit", "kernel_decline"):
                    if cost[ph]["layers"]:
                        prof.record(ph, flops=cost[ph]["flops"],
                                    hbm_bytes=cost[ph]["hbm_bytes"],
                                    count=cost[ph]["layers"])
            if eng.tracer is not None:
                # residency spans for the slots this chunk decoded; the
                # timestamp pair brackets dispatch→host-sync, taken
                # around the np.asarray(toks) sync that happens anyway
                pi = self._tm_pool_ids.setdefault(
                    key, len(self._tm_pool_ids))
                for slot, res in pool.active.items():
                    tid = pi * 1000 + slot
                    eng.tracer.name_thread(TR.PID_SLOTS, tid,
                                           f"g{pi}/slot{slot}",
                                           sort_index=tid)
                    eng.tracer.complete(f"rid{res.req.rid}", TR.PID_SLOTS,
                                        tid, t_decode, now, cat="slot")
                eng.tracer.complete(
                    f"decode g{pi}", TR.PID_SCHEDULER, 1, t_decode, now,
                    args={"batch": len(pool.active), "chunk": self.chunk})
            for slot in sorted(pool.active):
                inf = pool.active[slot]
                if not finite[slot]:
                    self._retire(inf, SLO.STATUS_FAILED, now,
                                 pool=pool, slot=slot)
                    continue
                if not inf.generated:
                    inf.metrics.first_token_t = now
                take = min(self.chunk,
                           inf.req.n_steps - len(inf.generated))
                eos = getattr(inf.req, "eos_id", None)
                new = _trim_eos(toks_np[slot, :take], eos).tolist()
                eos_hit = len(new) < take or (new and new[-1] == eos)
                inf.generated.extend(new)
                self.tokens_generated += len(new)
                if eos_hit or len(inf.generated) >= inf.req.n_steps:
                    self._retire(inf, SLO.STATUS_OK, now,
                                 pool=pool, slot=slot)
        eng._check_executable_guard()
        if tm_on:
            self._tm_tick(t0=tm_t0, d0=tm_d0, p0=tm_p0, tok0=tm_tok0)
        done, self._announce = self._announce, []
        return done

    # -- memory ledger ------------------------------------------------------
    def _ledger_entries(self) -> List[TM.PoolLedgerEntry]:
        """One ledger row per slot pool, from static byte figures the
        pools computed at create() — pure host arithmetic, no device
        reads.  ``queued_match`` marks pools whose geometry matches
        some waiting request whose routing is already known (a finished
        or in-flight chunked job, or a cached monolithic-fallback key);
        empty slots in pools matching NO queued work are *fragmented*
        bytes — capacity stranded on geometries the queue doesn't
        currently want.  Waiters that have not routed yet have no
        geometry to match and deliberately don't count."""
        queued = set()
        for inf in self.waiting:
            if inf.job is not None and inf.job.caches is not None:
                queued.add(KC.slot_geometry(inf.job.caches))
            elif inf.cached_key is not None:
                queued.add(inf.cached_key)
        entries = []
        for key, pool in self.pools.items():
            pid = self._tm_pool_ids.setdefault(key, len(self._tm_pool_ids))
            entries.append(TM.PoolLedgerEntry(
                pool=f"g{pid}", capacity=pool.capacity,
                occupied=len(pool.active),
                slot_payload_bytes=pool.slot_payload_bytes,
                slot_overhead_bytes=pool.slot_overhead_bytes,
                aux_bytes=pool.aux_bytes,
                queued_match=key in queued))
        return entries

    def ledger_snapshot(self) -> Optional[TM.LedgerSnapshot]:
        """Append the current device-memory picture to the engine's
        ledger and return it (None when the ledger is disabled)."""
        eng = self.engine
        led = eng.ledger
        if led is None:
            return None
        store = eng.prefix_store
        return led.update(
            t=self.clock(), tick=self.ticks,
            pools=self._ledger_entries(),
            prefix_device_bytes=(store.device_bytes
                                 if store is not None else 0),
            prefix_host_bytes=(store.host_bytes
                               if store is not None else 0))

    def _tm_tick(self, t0: float, d0: int, p0: int, tok0: int) -> None:
        """End-of-tick telemetry: delta counters, gauge refresh, the
        scheduler-track tick span + counter samples, and this tick's
        flight-recorder record.  Everything read here is host state the
        tick already materialized."""
        eng = self.engine
        now = self.clock()
        reg = eng.telemetry
        cap = sum(p.capacity for p in self.pools.values())
        reg.counter("serve_ticks_total").inc()
        reg.counter("serve_tokens_generated_total").inc(
            self.tokens_generated - tok0)
        reg.counter("serve_dispatches_total").inc(eng.dispatch_count - d0)
        reg.counter("flux_sa_transitions_total",
                    "sparsity-dial rung changes, either direction").inc(
            self.load.transitions - self._tm_transitions)
        self._tm_transitions = self.load.transitions
        # sparsity-rung transition events: the flight recorder's tick
        # stream shows exactly when (and in which direction) the dial
        # moved, next to the queue/batch state that drove it
        if eng.sa_level != self._tm_sa_level:
            self._tm_events.append(
                f"sa_level:{self._tm_sa_level}->{eng.sa_level}")
            self._tm_sa_level = eng.sa_level
        store = eng.prefix_store
        hits = misses = 0
        if store is not None:
            hits = store.hits - self._tm_prefix[0]
            misses = store.misses - self._tm_prefix[1]
            self._tm_prefix = (store.hits, store.misses)
        # snapshot the ledger BEFORE the gauge refresh so the exported
        # ledger gauges describe this tick, not the previous one
        snap = self.ledger_snapshot() if eng.ledger is not None else None
        eng._refresh_gauges()
        tracer = eng.tracer
        if tracer is not None:
            tracer.name_thread(TR.PID_SCHEDULER, 0, "ticks", sort_index=0)
            tracer.name_thread(TR.PID_SCHEDULER, 1, "decode", sort_index=1)
            tracer.complete(
                "tick", TR.PID_SCHEDULER, 0, t0, now,
                args={"tick": self.ticks,
                      "prefill_chunks": self.prefill_chunk_ticks - p0,
                      "dispatches": eng.dispatch_count - d0})
            tracer.counter("queue_depth", now,
                           {"waiting": len(self.waiting)})
            tracer.counter("slots", now,
                           {"active": self.n_active(), "capacity": cap})
            tracer.counter("sparsity", now,
                           {"sa_level": eng.sa_level,
                            "pressure": self.load.pressure})
            if snap is not None:
                # memory timeline: Perfetto step-plots the ledger tiers
                tracer.counter("ledger_bytes", now,
                               {"device": snap.device_bytes,
                                "pool_live": snap.pool_live_bytes,
                                "fragmentation": snap.fragmentation_bytes})
        fr = eng.flight_recorder
        if fr is not None:
            batch = {
                f"g{self._tm_pool_ids.setdefault(k, len(self._tm_pool_ids))}":
                p.occupancy() for k, p in self.pools.items()}
            fr.record(TM.TickRecord(
                tick=self.ticks, t=now,
                queue_depth=len(self.waiting),
                n_active=self.n_active(), capacity=cap,
                batch_by_geometry=batch,
                prefill_chunks=self.prefill_chunk_ticks - p0,
                dispatch_delta=eng.dispatch_count - d0,
                sa_level=eng.sa_level, pressure=self.load.pressure,
                prefix_device_bytes=(store.device_bytes
                                     if store is not None else 0),
                prefix_host_bytes=(store.host_bytes
                                   if store is not None else 0),
                prefix_hits=hits, prefix_misses=misses,
                ledger_device_bytes=(snap.device_bytes
                                     if snap is not None else 0),
                ledger_fragmentation_bytes=(snap.fragmentation_bytes
                                            if snap is not None else 0),
                mesh=eng.mesh_shape(),
                events=tuple(self._tm_events)))
        self._tm_events = []

    def drain(self) -> Dict[int, FinishedRequest]:
        """Tick until every submitted request has retired (finished,
        shed, expired, cancelled, or quarantined), then close the
        scheduler: further ``submit`` calls raise instead of queueing
        on a scheduler nothing will ever tick again."""
        guard = 0
        while self.waiting or any(p.active for p in self.pools.values()):
            before = (self.tokens_generated, self.n_active(),
                      len(self.finished), self.prefill_chunk_ticks)
            self.tick()
            progressed = before != (self.tokens_generated, self.n_active(),
                                    len(self.finished),
                                    self.prefill_chunk_ticks)
            guard = 0 if progressed else guard + 1
            if guard > 10_000:
                raise RuntimeError(
                    "scheduler made no progress (no tokens, admissions or "
                    "completions) for 10k ticks — a request can neither "
                    "finish nor admit (check slots_per_bucket and "
                    "priorities)")
        self.closed = True
        return {f.rid: f for f in self.finished}

    # -- introspection ------------------------------------------------------
    def n_active(self) -> int:
        return sum(len(p.active) for p in self.pools.values())

    def n_geometries(self) -> int:
        return len(self.pools)
