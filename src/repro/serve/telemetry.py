"""Serving metrics registry + tick flight recorder (DESIGN.md
§Observability).

Host-side telemetry primitives for the serving stack:

  MetricsRegistry — counters, gauges and histograms (with bounded
      quantile digests) keyed by (name, labels), rendered as Prometheus
      text exposition format (``ServeEngine.metrics_text()``,
      ``launch/serve.py --metrics-out``).  Histograms render as
      Prometheus *summaries*: ``name{quantile="0.5"} …`` plus
      ``name_sum`` / ``name_count``.
  FlightRecorder — a bounded ring of per-tick :class:`TickRecord`
      snapshots (batch size per geometry, prefill chunks, dispatch
      delta, occupancy, queue depth, load pressure, sa_level, prefix
      tier bytes, shed/quarantine events).  After an incident,
      ``engine.flight_recorder.dump()`` returns the last N ticks as
      plain dicts — the serving equivalent of a black box.
  MemoryLedger — unified byte accounting across slot pools, prefix-cache
      tiers and params: per-pool live/stranded/overhead split, a
      fragmentation metric (empty-slot bytes in pools whose geometry
      matches no queued work), and a device-byte high watermark.  The
      scheduler feeds it already-known host integers (static shapes ×
      occupancy); it never reads a device buffer.
  TickProfiler — sampled per-tick latency attribution.  Every Nth tick
      the scheduler brackets each phase (queue / prefill_chunk / admit /
      decode, split kernel-hit vs kernel-decline) with timed
      device-sync boundaries and records host-vs-device seconds plus
      the analytic expressed FLOPs/HBM cost from ``launch/hlo_costs``;
      unsampled ticks never sync.  ``report()`` emits the
      achieved-vs-expressed efficiency table.

Design rules (enforced by tests/test_telemetry.py):

  * Host-side only.  Nothing in this module touches jax: no traced
    values, no jit, no device transfers.  Every recorded quantity is
    already-materialized host state (Python ints/floats the scheduler
    maintains anyway), so telemetry can never add a device sync or a
    compiled executable to the tick loop.
  * Allocation-light.  Histograms keep a bounded reservoir (Algorithm-R
    replacement when full, seeded per instance), the flight recorder is
    a ``deque(maxlen=…)``, and metric objects are created once and
    mutated in place.
  * Deterministic.  Any sampling decision is driven by injectable
    per-instance state (histogram reservoir seeds, profiler/probe
    cadence counters), never module-level randomness — the bench
    telemetry-overhead gate replays identical workloads and must not
    eat sampling noise.
  * Off is free.  The scheduler/engine hold ``None`` instead of these
    objects when telemetry is disabled; the instrumented paths reduce
    to a single ``is not None`` test, keeping the telemetry-off run
    bitwise-identical (and executable-guard-identical) to the
    uninstrumented scheduler.

``python -m repro.serve.telemetry metrics.prom`` validates a scraped
exposition file (used by the CI telemetry smoke).
"""
from __future__ import annotations

import math
import random
import re
from collections import OrderedDict, deque
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple
from zlib import crc32 as _crc32

# ---------------------------------------------------------------------------
# Quantile digest helpers (shared with benchmarks/common.py)
# ---------------------------------------------------------------------------


def quantile(xs: Iterable[float], q: float) -> float:
    """The q-th percentile (0..100) of the finite values in ``xs``;
    NaN when none are finite.  Linear interpolation between order
    statistics — the same estimator ``np.percentile`` defaults to, in
    pure Python so the registry never imports numpy on the hot path."""
    vals = sorted(x for x in xs if math.isfinite(x))
    if not vals:
        return float("nan")
    if len(vals) == 1:
        return float(vals[0])
    pos = (q / 100.0) * (len(vals) - 1)
    lo = max(0, min(int(math.floor(pos)), len(vals) - 1))
    hi = max(0, min(lo + 1, len(vals) - 1))
    frac = pos - lo
    return float(vals[lo] * (1.0 - frac) + vals[hi] * frac)


def summarize(xs: Iterable[float],
              qs: Sequence[float] = (50, 95, 99)) -> Dict[str, float]:
    """{"p50": …, "p95": …, "p99": …} digest of ``xs`` (NaN-filtered).
    The one percentile helper serving benches share (benchmarks/common
    re-exports it) instead of per-file copies."""
    vals = [x for x in xs if math.isfinite(x)]
    return {f"p{q:g}": quantile(vals, q) for q in qs}


# ---------------------------------------------------------------------------
# Metric primitives
# ---------------------------------------------------------------------------


class Counter:
    """Monotonically increasing count."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"Counter.inc({n}): counters only go up — "
                             f"use a Gauge for values that can fall")
        self.value += n


class Gauge:
    """Point-in-time value (set, not accumulated)."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Streaming distribution with a bounded reservoir.

    Keeps exact ``count``/``sum``/``min``/``max`` plus a reservoir of at
    most ``reservoir`` observations for quantiles, maintained with
    Vitter's Algorithm R driven by an *injectable* seeded generator
    (``random.Random(seed)``) — the sample is uniform over the stream,
    allocation-bounded, and **deterministic for a fixed seed and
    observation order**, so two runs of the same workload render the
    same quantile digests (the bench telemetry-overhead gate compares
    instrumented runs and must not eat sampling noise).  Faithful
    enough for p50/p95/p99 serving digests."""
    __slots__ = ("count", "sum", "min", "max", "_res", "_cap", "_seen",
                 "_rng")

    def __init__(self, reservoir: int = 1024, seed: int = 0):
        if reservoir < 2:
            raise ValueError(f"Histogram: reservoir={reservoir} must be "
                             f">= 2 to hold a distribution")
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._res: List[float] = []
        self._cap = int(reservoir)
        self._seen = 0
        # per-instance generator: module-level randomness would couple
        # histograms to each other (and to anything else using
        # ``random``), destroying replayability
        self._rng = random.Random(seed)

    def observe(self, v: float) -> None:
        v = float(v)
        if not math.isfinite(v):
            return  # NaN TTFTs (never-served requests) are not latencies
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        self._seen += 1
        if len(self._res) < self._cap:
            self._res.append(v)
            return
        # Algorithm R: keep observation i with probability cap/i, into a
        # uniformly chosen slot — every prefix of the stream is equally
        # represented, unlike stride decimation which over-weights
        # whichever phase of the run aligned with the stride
        j = self._rng.randrange(self._seen)
        if j < self._cap:
            self._res[j] = v

    def percentile(self, q: float) -> float:
        return quantile(self._res, q)

    def digest(self, qs: Sequence[float] = (50, 95, 99)) -> Dict[str, float]:
        return {f"p{q:g}": self.percentile(q) for q in qs}


_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def _escape(v: str) -> str:
    return v.replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")


def _unescape(v: str) -> str:
    """Inverse of :func:`_escape` — a scraped label value must round-trip
    to the string that was observed, or escaped payloads (request ids
    with quotes, multi-line event text) silently corrupt on re-ingest."""
    out: List[str] = []
    i, n = 0, len(v)
    while i < n:
        c = v[i]
        if c == "\\" and i + 1 < n:
            nxt = v[i + 1]
            if nxt == "n":
                out.append("\n")
            elif nxt in ('"', "\\"):
                out.append(nxt)
            else:  # unknown escape: Prometheus keeps it verbatim
                out.append(c)
                out.append(nxt)
            i += 2
            continue
        out.append(c)
        i += 1
    return "".join(out)


def _fmt(v: float) -> str:
    if math.isnan(v):
        return "NaN"
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(float(v))


class MetricsRegistry:
    """Named metric store with Prometheus text rendering.

    ``counter``/``gauge``/``histogram`` get-or-create the metric for a
    (name, labels) pair, so call sites just
    ``reg.counter("requests_total", status="ok").inc()``; creation cost
    is paid once and steady-state updates are a dict hit plus a float
    add."""

    def __init__(self, seed: int = 0):
        # name -> (kind, help); (name, labels) -> metric object
        self._meta: "OrderedDict[str, Tuple[str, str]]" = OrderedDict()
        self._metrics: Dict[Tuple[str, Tuple[Tuple[str, str], ...]],
                            object] = {}
        # base seed for histogram reservoirs; each histogram derives a
        # distinct stable seed from its (name, labels) key so identical
        # runs render identical digests
        self._seed = int(seed)

    # -- registration --------------------------------------------------------
    def _get(self, kind: str, name: str, help_: str, labels: Dict[str, str],
             factory):
        if name not in self._meta:
            if not _NAME_RE.match(name):
                raise ValueError(
                    f"metric name {name!r} is not a valid Prometheus "
                    f"metric name ([a-zA-Z_:][a-zA-Z0-9_:]*)")
            for k in labels:
                if not _LABEL_RE.match(k):
                    raise ValueError(
                        f"label name {k!r} on metric {name!r} is not a "
                        f"valid Prometheus label name")
            self._meta[name] = (kind, help_)
        elif self._meta[name][0] != kind:
            raise ValueError(
                f"metric {name!r} already registered as "
                f"{self._meta[name][0]}, cannot re-register as {kind}")
        key = (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics[key] = factory()
        return m

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get("counter", name, help, labels, Counter)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get("gauge", name, help, labels, Gauge)

    def histogram(self, name: str, help: str = "", reservoir: int = 1024,
                  **labels) -> Histogram:
        # hash() is salted per-process for str; zlib.crc32 of the key is
        # stable across runs, which is the whole point of seeding
        key = ",".join([name] + sorted(f"{k}={v}"
                                       for k, v in labels.items()))
        seed = self._seed ^ _crc32(key.encode())
        return self._get("summary", name, help, labels,
                         lambda: Histogram(reservoir, seed=seed))

    # -- rendering -----------------------------------------------------------
    @staticmethod
    def _labels_str(labels: Tuple[Tuple[str, str], ...],
                    extra: Tuple[Tuple[str, str], ...] = ()) -> str:
        items = labels + extra
        if not items:
            return ""
        return ("{" + ",".join(f'{k}="{_escape(v)}"' for k, v in items)
                + "}")

    def render(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        out: List[str] = []
        for name, (kind, help_) in self._meta.items():
            if help_:
                out.append(f"# HELP {name} {help_}")
            out.append(f"# TYPE {name} {kind}")
            for (mname, labels), m in self._metrics.items():
                if mname != name:
                    continue
                if kind in ("counter", "gauge"):
                    out.append(f"{name}{self._labels_str(labels)} "
                               f"{_fmt(m.value)}")
                    continue
                for q in (0.5, 0.95, 0.99):
                    out.append(
                        f"{name}"
                        f"{self._labels_str(labels, (('quantile', f'{q:g}'),))}"
                        f" {_fmt(m.percentile(q * 100))}")
                out.append(f"{name}_sum{self._labels_str(labels)} "
                           f"{_fmt(m.sum)}")
                out.append(f"{name}_count{self._labels_str(labels)} "
                           f"{_fmt(float(m.count))}")
        return "\n".join(out) + "\n"


# -- exposition-format validation (tests + CI smoke) -------------------------

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r"\s+(?P<value>[+-]?(?:\d+\.?\d*(?:[eE][+-]?\d+)?|Inf|NaN))"
    r"(?:\s+\d+)?$")
_LABEL_PAIR_RE = re.compile(
    r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def parse_prometheus_text(text: str) -> Dict[str, List[Tuple[Dict[str, str],
                                                             float]]]:
    """Parse (and thereby validate) Prometheus text exposition.

    Returns {metric_name: [(labels, value), …]}.  Raises ``ValueError``
    on any malformed line — the CI telemetry smoke and the tests call
    this on ``ServeEngine.metrics_text()`` output so a rendering
    regression fails loudly instead of producing an unscrapeable
    endpoint."""
    samples: Dict[str, List[Tuple[Dict[str, str], float]]] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) < 3 or parts[1] not in ("HELP", "TYPE"):
                raise ValueError(
                    f"line {lineno}: malformed comment {line!r} — only "
                    f"'# HELP <name> …' and '# TYPE <name> <kind>' are "
                    f"valid exposition comments")
            if (parts[1] == "TYPE"
                    and parts[3].split()[0] not in (
                        "counter", "gauge", "histogram", "summary",
                        "untyped")):
                raise ValueError(
                    f"line {lineno}: unknown metric type in {line!r}")
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(
                f"line {lineno}: {line!r} is not a valid Prometheus "
                f"sample line")
        labels: Dict[str, str] = {}
        if m.group("labels"):
            body = m.group("labels")
            consumed = 0
            for pm in _LABEL_PAIR_RE.finditer(body):
                labels[pm.group(1)] = _unescape(pm.group(2))
                consumed = pm.end()
            rest = body[consumed:].strip().strip(",")
            if rest:
                raise ValueError(
                    f"line {lineno}: malformed label body {body!r}")
        v = m.group("value")
        val = float("nan") if v == "NaN" else float(v.replace("Inf", "inf"))
        samples.setdefault(m.group("name"), []).append((labels, val))
    if not samples:
        raise ValueError("no metric samples found in exposition text")
    return samples


# ---------------------------------------------------------------------------
# Tick flight recorder
# ---------------------------------------------------------------------------


@dataclass
class TickRecord:
    """One scheduler tick, as the flight recorder remembers it.  Every
    field is host state the scheduler already maintains — recording one
    is a dataclass allocation plus dict copies, never a device read."""
    tick: int                       # scheduler tick counter
    t: float                        # tick timestamp (scheduler clock)
    queue_depth: int                # waiting requests after admission
    n_active: int                   # resident decode slots, all pools
    capacity: int                   # total decode slots, all pools
    batch_by_geometry: Dict[str, int]  # active slots per geometry bucket
    prefill_chunks: int             # prefill chunks streamed this tick
    dispatch_delta: int             # compiled calls issued this tick
    sa_level: int                   # sparsity rung after this tick
    pressure: float                 # LoadTracker queue-pressure signal
    prefix_device_bytes: int = 0    # prefix store occupancy, device tier
    prefix_host_bytes: int = 0      # prefix store occupancy, host tier
    prefix_hits: int = 0            # prefix-cache hits this tick
    prefix_misses: int = 0          # prefix-cache misses this tick
    ledger_device_bytes: int = 0    # MemoryLedger total (0 = ledger off)
    ledger_fragmentation_bytes: int = 0  # stranded empty-slot bytes
    mesh: Optional[Tuple[int, ...]] = None  # device-mesh shape, None =
                                    # single-device serving
    events: Tuple[str, ...] = ()    # non-ok retirements "status:rid",
                                    # sa_level moves "sa_level:old->new"

    def as_dict(self) -> Dict[str, object]:
        d = self.__dict__.copy()
        d["batch_by_geometry"] = dict(self.batch_by_geometry)
        d["mesh"] = list(self.mesh) if self.mesh is not None else None
        d["events"] = list(self.events)
        return d


class FlightRecorder:
    """Bounded ring of :class:`TickRecord` — the last ``capacity``
    scheduler ticks, oldest evicted first."""

    def __init__(self, capacity: int = 512):
        if capacity < 1:
            raise ValueError(
                f"FlightRecorder: capacity={capacity} must be >= 1 tick")
        self.capacity = int(capacity)
        self._ring: "deque[TickRecord]" = deque(maxlen=self.capacity)
        self.recorded = 0  # lifetime ticks seen (>= len(ring))

    def record(self, rec: TickRecord) -> None:
        self._ring.append(rec)
        self.recorded += 1

    def __len__(self) -> int:
        return len(self._ring)

    def dump(self) -> List[Dict[str, object]]:
        """The retained ticks, oldest first, as plain dicts (JSON-ready
        incident payload)."""
        return [r.as_dict() for r in self._ring]

    def last(self) -> Optional[TickRecord]:
        return self._ring[-1] if self._ring else None

    def clear(self) -> None:
        self._ring.clear()


# ---------------------------------------------------------------------------
# Memory ledger
# ---------------------------------------------------------------------------


@dataclass
class PoolLedgerEntry:
    """One slot pool's byte accounting, from static shapes × occupancy.

    ``slot_payload_bytes``/``slot_overhead_bytes`` are computed once at
    pool creation (cache shapes never change over a pool's lifetime);
    the per-tick update only multiplies them by host-side occupancy
    counts, so the ledger adds no device reads to the tick loop."""
    pool: str                   # geometry bucket id ("g0", "g1", …)
    capacity: int               # total slots
    occupied: int               # slots holding a resident request
    slot_payload_bytes: int     # KV/state payload bytes per slot
    slot_overhead_bytes: int    # positions/length metadata per slot
    aux_bytes: int              # pool-level logits/pos buffers
    queued_match: bool          # any queued request routes here?

    @property
    def live_bytes(self) -> int:
        return self.occupied * self.slot_payload_bytes

    @property
    def stranded_bytes(self) -> int:
        """Payload bytes held by empty slots — capacity paid for but
        not serving anyone right now."""
        return (self.capacity - self.occupied) * self.slot_payload_bytes

    @property
    def overhead_bytes(self) -> int:
        return self.capacity * self.slot_overhead_bytes + self.aux_bytes

    @property
    def total_bytes(self) -> int:
        return self.capacity * (self.slot_payload_bytes
                                + self.slot_overhead_bytes) + self.aux_bytes

    @property
    def fragmentation_bytes(self) -> int:
        """Stranded bytes that cannot help the queue: empty-slot payload
        in a pool whose geometry matches no queued request.  This is the
        signal the ROADMAP's pool-rebalancing tentpole needs — bytes a
        defragmenting allocator could reclaim right now."""
        return 0 if self.queued_match else self.stranded_bytes

    def as_dict(self) -> Dict[str, object]:
        return {
            "pool": self.pool,
            "capacity": self.capacity,
            "occupied": self.occupied,
            "live_bytes": self.live_bytes,
            "stranded_bytes": self.stranded_bytes,
            "overhead_bytes": self.overhead_bytes,
            "total_bytes": self.total_bytes,
            "fragmentation_bytes": self.fragmentation_bytes,
            "queued_match": self.queued_match,
        }


@dataclass
class LedgerSnapshot:
    """Point-in-time unified byte accounting across every HBM consumer
    the serving stack knows about."""
    t: float
    tick: int
    pools: Tuple[PoolLedgerEntry, ...]
    prefix_device_bytes: int
    prefix_host_bytes: int
    params_bytes: int
    device_high_watermark_bytes: int

    @property
    def pool_live_bytes(self) -> int:
        return sum(p.live_bytes for p in self.pools)

    @property
    def pool_stranded_bytes(self) -> int:
        return sum(p.stranded_bytes for p in self.pools)

    @property
    def pool_overhead_bytes(self) -> int:
        return sum(p.overhead_bytes for p in self.pools)

    @property
    def pool_payload_bytes(self) -> int:
        # live + stranded == capacity × per-slot payload, the quantity
        # kv_cache_stats reports as payload_bytes for the pool caches
        return self.pool_live_bytes + self.pool_stranded_bytes

    @property
    def fragmentation_bytes(self) -> int:
        return sum(p.fragmentation_bytes for p in self.pools)

    @property
    def device_bytes(self) -> int:
        """Everything resident in device memory that the ledger tracks
        (host prefix tier excluded by definition)."""
        return (self.pool_payload_bytes + self.pool_overhead_bytes
                + self.prefix_device_bytes + self.params_bytes)

    def reconcile(self, payload_bytes: int, overhead_bytes: int,
                  prefix_device_bytes: int,
                  prefix_host_bytes: int) -> Dict[str, int]:
        """Deltas vs an independent ``kv_cache_stats`` walk of the same
        pools+prefix store.  Payload and prefix tiers must agree exactly
        (both sides are shape arithmetic over the same arrays); overhead
        may differ by the pool-level aux buffers (logits/pos) that
        kv_cache_stats does not see — callers assert accordingly."""
        return {
            "payload_delta": self.pool_payload_bytes - int(payload_bytes),
            "overhead_delta": self.pool_overhead_bytes - int(overhead_bytes),
            "prefix_device_delta":
                self.prefix_device_bytes - int(prefix_device_bytes),
            "prefix_host_delta":
                self.prefix_host_bytes - int(prefix_host_bytes),
        }

    def as_dict(self) -> Dict[str, object]:
        return {
            "t": self.t,
            "tick": self.tick,
            "pools": [p.as_dict() for p in self.pools],
            "pool_live_bytes": self.pool_live_bytes,
            "pool_stranded_bytes": self.pool_stranded_bytes,
            "pool_overhead_bytes": self.pool_overhead_bytes,
            "fragmentation_bytes": self.fragmentation_bytes,
            "prefix_device_bytes": self.prefix_device_bytes,
            "prefix_host_bytes": self.prefix_host_bytes,
            "params_bytes": self.params_bytes,
            "device_bytes": self.device_bytes,
            "device_high_watermark_bytes": self.device_high_watermark_bytes,
        }


class MemoryLedger:
    """Unified byte-accounting registry.  The scheduler calls
    :meth:`update` each tick with per-pool occupancy; everything else
    (params bytes, per-slot byte constants) was measured once at
    engine/pool construction.  Tracks the device-byte high watermark
    across updates."""

    def __init__(self, params_bytes: int = 0):
        self.params_bytes = int(params_bytes)
        self.high_watermark = 0
        self.updates = 0
        self._last: Optional[LedgerSnapshot] = None

    def update(self, *, t: float, tick: int,
               pools: Sequence[PoolLedgerEntry],
               prefix_device_bytes: int = 0,
               prefix_host_bytes: int = 0) -> LedgerSnapshot:
        snap = LedgerSnapshot(
            t=float(t), tick=int(tick), pools=tuple(pools),
            prefix_device_bytes=int(prefix_device_bytes),
            prefix_host_bytes=int(prefix_host_bytes),
            params_bytes=self.params_bytes,
            device_high_watermark_bytes=self.high_watermark)
        if snap.device_bytes > self.high_watermark:
            self.high_watermark = snap.device_bytes
            snap.device_high_watermark_bytes = self.high_watermark
        self.updates += 1
        self._last = snap
        return snap

    def last(self) -> Optional[LedgerSnapshot]:
        return self._last


# ---------------------------------------------------------------------------
# Per-tick cost attribution profiler
# ---------------------------------------------------------------------------


@dataclass
class PhaseStat:
    """Accumulated attribution for one tick phase across all sampled
    ticks.  ``device_s`` is wall time between timed sync boundaries
    (host dispatch + device compute for that phase's work); ``host_s``
    is the phase's pure-host bookkeeping time.  ``flops``/``hbm_bytes``
    are the analytic *expressed* cost from ``launch/hlo_costs`` for the
    work the phase dispatched."""
    phase: str
    ticks: int = 0
    host_s: float = 0.0
    device_s: float = 0.0
    flops: float = 0.0
    hbm_bytes: float = 0.0
    count: int = 0  # phase-specific unit (chunks, decode steps, layers)

    def as_dict(self) -> Dict[str, object]:
        d = {
            "phase": self.phase, "ticks": self.ticks, "count": self.count,
            "host_s": self.host_s, "device_s": self.device_s,
            "expressed_flops": self.flops,
            "expressed_hbm_bytes": self.hbm_bytes,
        }
        wall = self.host_s + self.device_s
        d["host_frac"] = self.host_s / wall if wall > 0 else 0.0
        # achieved-vs-expressed: what rate did the device sustain against
        # the analytic cost the phase expressed?
        d["achieved_gflops_per_s"] = (
            self.flops / self.device_s / 1e9 if self.device_s > 0 else 0.0)
        d["achieved_gbytes_per_s"] = (
            self.hbm_bytes / self.device_s / 1e9
            if self.device_s > 0 else 0.0)
        return d


class TickProfiler:
    """Sampled per-tick latency/cost attribution.

    ``should_sample(tick)`` is a modulus test on the host tick counter —
    deterministic, so paired bench runs profile the same ticks.  On a
    sampled tick the *scheduler* brackets each phase with its clock and
    a device sync (the profiler itself never imports jax) and calls
    :meth:`record`; unsampled ticks skip both the syncs and the calls
    entirely, keeping the steady-state path dispatch-identical."""

    def __init__(self, every: int = 32):
        if every < 1:
            raise ValueError(
                f"TickProfiler: every={every} must be >= 1 "
                f"(1 = profile every tick)")
        self.every = int(every)
        self.sampled_ticks = 0
        self._phases: "OrderedDict[str, PhaseStat]" = OrderedDict()

    def should_sample(self, tick: int) -> bool:
        return tick % self.every == 0

    def note_sampled_tick(self) -> None:
        self.sampled_ticks += 1

    def record(self, phase: str, *, host_s: float = 0.0,
               device_s: float = 0.0, flops: float = 0.0,
               hbm_bytes: float = 0.0, count: int = 1) -> None:
        st = self._phases.get(phase)
        if st is None:
            st = self._phases[phase] = PhaseStat(phase=phase)
        st.ticks += 1
        st.host_s += float(host_s)
        st.device_s += float(device_s)
        st.flops += float(flops)
        st.hbm_bytes += float(hbm_bytes)
        st.count += int(count)

    def report(self) -> Dict[str, object]:
        """Per-phase achieved-vs-expressed efficiency table, JSON-ready."""
        phases = [st.as_dict() for st in self._phases.values()]
        total_host = sum(p["host_s"] for p in phases)
        total_dev = sum(p["device_s"] for p in phases)
        return {
            "every": self.every,
            "sampled_ticks": self.sampled_ticks,
            "total_host_s": total_host,
            "total_device_s": total_dev,
            "phases": phases,
        }


def main(argv: Optional[List[str]] = None) -> int:
    """CLI validator: ``python -m repro.serve.telemetry metrics.prom``
    parses an exposition file and reports the metric census (exit 1 on
    malformed input) — the CI smoke's 'does the endpoint scrape' gate."""
    import sys
    args = sys.argv[1:] if argv is None else argv
    if len(args) != 1:
        print("usage: python -m repro.serve.telemetry <metrics.prom>",
              file=sys.stderr)
        return 2
    with open(args[0]) as f:
        text = f.read()
    try:
        samples = parse_prometheus_text(text)
    except ValueError as e:
        print(f"INVALID prometheus text: {e}", file=sys.stderr)
        return 1
    n = sum(len(v) for v in samples.values())
    print(f"ok: {len(samples)} metrics, {n} samples")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
