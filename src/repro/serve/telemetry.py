"""Serving metrics registry + tick flight recorder (DESIGN.md
§Observability).

Two host-side telemetry primitives for the serving stack:

  MetricsRegistry — counters, gauges and histograms (with bounded
      quantile digests) keyed by (name, labels), rendered as Prometheus
      text exposition format (``ServeEngine.metrics_text()``,
      ``launch/serve.py --metrics-out``).  Histograms render as
      Prometheus *summaries*: ``name{quantile="0.5"} …`` plus
      ``name_sum`` / ``name_count``.
  FlightRecorder — a bounded ring of per-tick :class:`TickRecord`
      snapshots (batch size per geometry, prefill chunks, dispatch
      delta, occupancy, queue depth, load pressure, sa_level, prefix
      tier bytes, shed/quarantine events).  After an incident,
      ``engine.flight_recorder.dump()`` returns the last N ticks as
      plain dicts — the serving equivalent of a black box.

Design rules (enforced by tests/test_telemetry.py):

  * Host-side only.  Nothing in this module touches jax: no traced
    values, no jit, no device transfers.  Every recorded quantity is
    already-materialized host state (Python ints/floats the scheduler
    maintains anyway), so telemetry can never add a device sync or a
    compiled executable to the tick loop.
  * Allocation-light.  Histograms keep a bounded reservoir (decimated
    in place when full), the flight recorder is a ``deque(maxlen=…)``,
    and metric objects are created once and mutated in place.
  * Off is free.  The scheduler/engine hold ``None`` instead of these
    objects when telemetry is disabled; the instrumented paths reduce
    to a single ``is not None`` test, keeping the telemetry-off run
    bitwise-identical (and executable-guard-identical) to the
    uninstrumented scheduler.

``python -m repro.serve.telemetry metrics.prom`` validates a scraped
exposition file (used by the CI telemetry smoke).
"""
from __future__ import annotations

import math
import re
from collections import OrderedDict, deque
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Quantile digest helpers (shared with benchmarks/common.py)
# ---------------------------------------------------------------------------


def quantile(xs: Iterable[float], q: float) -> float:
    """The q-th percentile (0..100) of the finite values in ``xs``;
    NaN when none are finite.  Linear interpolation between order
    statistics — the same estimator ``np.percentile`` defaults to, in
    pure Python so the registry never imports numpy on the hot path."""
    vals = sorted(x for x in xs if math.isfinite(x))
    if not vals:
        return float("nan")
    if len(vals) == 1:
        return float(vals[0])
    pos = (q / 100.0) * (len(vals) - 1)
    lo = max(0, min(int(math.floor(pos)), len(vals) - 1))
    hi = max(0, min(lo + 1, len(vals) - 1))
    frac = pos - lo
    return float(vals[lo] * (1.0 - frac) + vals[hi] * frac)


def summarize(xs: Iterable[float],
              qs: Sequence[float] = (50, 95, 99)) -> Dict[str, float]:
    """{"p50": …, "p95": …, "p99": …} digest of ``xs`` (NaN-filtered).
    The one percentile helper serving benches share (benchmarks/common
    re-exports it) instead of per-file copies."""
    vals = [x for x in xs if math.isfinite(x)]
    return {f"p{q:g}": quantile(vals, q) for q in qs}


# ---------------------------------------------------------------------------
# Metric primitives
# ---------------------------------------------------------------------------


class Counter:
    """Monotonically increasing count."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"Counter.inc({n}): counters only go up — "
                             f"use a Gauge for values that can fall")
        self.value += n


class Gauge:
    """Point-in-time value (set, not accumulated)."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Streaming distribution with a bounded reservoir.

    Keeps exact ``count``/``sum``/``min``/``max`` plus a reservoir of at
    most ``reservoir`` observations for quantiles.  When the reservoir
    fills, it is decimated in place (every 2nd sample kept) and the
    acceptance stride doubles — deterministic, allocation-bounded, and
    faithful enough for p50/p95/p99 serving digests."""
    __slots__ = ("count", "sum", "min", "max", "_res", "_cap", "_stride",
                 "_seen")

    def __init__(self, reservoir: int = 1024):
        if reservoir < 2:
            raise ValueError(f"Histogram: reservoir={reservoir} must be "
                             f">= 2 to hold a distribution")
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._res: List[float] = []
        self._cap = int(reservoir)
        self._stride = 1
        self._seen = 0

    def observe(self, v: float) -> None:
        v = float(v)
        if not math.isfinite(v):
            return  # NaN TTFTs (never-served requests) are not latencies
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        self._seen += 1
        if self._seen % self._stride:
            return
        if len(self._res) >= self._cap:
            del self._res[::2]
            self._stride *= 2
            if self._seen % self._stride:
                return
        self._res.append(v)

    def percentile(self, q: float) -> float:
        return quantile(self._res, q)

    def digest(self, qs: Sequence[float] = (50, 95, 99)) -> Dict[str, float]:
        return {f"p{q:g}": self.percentile(q) for q in qs}


_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def _escape(v: str) -> str:
    return v.replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")


def _fmt(v: float) -> str:
    if math.isnan(v):
        return "NaN"
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(float(v))


class MetricsRegistry:
    """Named metric store with Prometheus text rendering.

    ``counter``/``gauge``/``histogram`` get-or-create the metric for a
    (name, labels) pair, so call sites just
    ``reg.counter("requests_total", status="ok").inc()``; creation cost
    is paid once and steady-state updates are a dict hit plus a float
    add."""

    def __init__(self):
        # name -> (kind, help); (name, labels) -> metric object
        self._meta: "OrderedDict[str, Tuple[str, str]]" = OrderedDict()
        self._metrics: Dict[Tuple[str, Tuple[Tuple[str, str], ...]],
                            object] = {}

    # -- registration --------------------------------------------------------
    def _get(self, kind: str, name: str, help_: str, labels: Dict[str, str],
             factory):
        if name not in self._meta:
            if not _NAME_RE.match(name):
                raise ValueError(
                    f"metric name {name!r} is not a valid Prometheus "
                    f"metric name ([a-zA-Z_:][a-zA-Z0-9_:]*)")
            for k in labels:
                if not _LABEL_RE.match(k):
                    raise ValueError(
                        f"label name {k!r} on metric {name!r} is not a "
                        f"valid Prometheus label name")
            self._meta[name] = (kind, help_)
        elif self._meta[name][0] != kind:
            raise ValueError(
                f"metric {name!r} already registered as "
                f"{self._meta[name][0]}, cannot re-register as {kind}")
        key = (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics[key] = factory()
        return m

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get("counter", name, help, labels, Counter)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get("gauge", name, help, labels, Gauge)

    def histogram(self, name: str, help: str = "", reservoir: int = 1024,
                  **labels) -> Histogram:
        return self._get("summary", name, help, labels,
                         lambda: Histogram(reservoir))

    # -- rendering -----------------------------------------------------------
    @staticmethod
    def _labels_str(labels: Tuple[Tuple[str, str], ...],
                    extra: Tuple[Tuple[str, str], ...] = ()) -> str:
        items = labels + extra
        if not items:
            return ""
        return ("{" + ",".join(f'{k}="{_escape(v)}"' for k, v in items)
                + "}")

    def render(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        out: List[str] = []
        for name, (kind, help_) in self._meta.items():
            if help_:
                out.append(f"# HELP {name} {help_}")
            out.append(f"# TYPE {name} {kind}")
            for (mname, labels), m in self._metrics.items():
                if mname != name:
                    continue
                if kind in ("counter", "gauge"):
                    out.append(f"{name}{self._labels_str(labels)} "
                               f"{_fmt(m.value)}")
                    continue
                for q in (0.5, 0.95, 0.99):
                    out.append(
                        f"{name}"
                        f"{self._labels_str(labels, (('quantile', f'{q:g}'),))}"
                        f" {_fmt(m.percentile(q * 100))}")
                out.append(f"{name}_sum{self._labels_str(labels)} "
                           f"{_fmt(m.sum)}")
                out.append(f"{name}_count{self._labels_str(labels)} "
                           f"{_fmt(float(m.count))}")
        return "\n".join(out) + "\n"


# -- exposition-format validation (tests + CI smoke) -------------------------

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r"\s+(?P<value>[+-]?(?:\d+\.?\d*(?:[eE][+-]?\d+)?|Inf|NaN))"
    r"(?:\s+\d+)?$")
_LABEL_PAIR_RE = re.compile(
    r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def parse_prometheus_text(text: str) -> Dict[str, List[Tuple[Dict[str, str],
                                                             float]]]:
    """Parse (and thereby validate) Prometheus text exposition.

    Returns {metric_name: [(labels, value), …]}.  Raises ``ValueError``
    on any malformed line — the CI telemetry smoke and the tests call
    this on ``ServeEngine.metrics_text()`` output so a rendering
    regression fails loudly instead of producing an unscrapeable
    endpoint."""
    samples: Dict[str, List[Tuple[Dict[str, str], float]]] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) < 3 or parts[1] not in ("HELP", "TYPE"):
                raise ValueError(
                    f"line {lineno}: malformed comment {line!r} — only "
                    f"'# HELP <name> …' and '# TYPE <name> <kind>' are "
                    f"valid exposition comments")
            if (parts[1] == "TYPE"
                    and parts[3].split()[0] not in (
                        "counter", "gauge", "histogram", "summary",
                        "untyped")):
                raise ValueError(
                    f"line {lineno}: unknown metric type in {line!r}")
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(
                f"line {lineno}: {line!r} is not a valid Prometheus "
                f"sample line")
        labels: Dict[str, str] = {}
        if m.group("labels"):
            body = m.group("labels")
            consumed = 0
            for pm in _LABEL_PAIR_RE.finditer(body):
                labels[pm.group(1)] = pm.group(2)
                consumed = pm.end()
            rest = body[consumed:].strip().strip(",")
            if rest:
                raise ValueError(
                    f"line {lineno}: malformed label body {body!r}")
        v = m.group("value")
        val = float("nan") if v == "NaN" else float(v.replace("Inf", "inf"))
        samples.setdefault(m.group("name"), []).append((labels, val))
    if not samples:
        raise ValueError("no metric samples found in exposition text")
    return samples


# ---------------------------------------------------------------------------
# Tick flight recorder
# ---------------------------------------------------------------------------


@dataclass
class TickRecord:
    """One scheduler tick, as the flight recorder remembers it.  Every
    field is host state the scheduler already maintains — recording one
    is a dataclass allocation plus dict copies, never a device read."""
    tick: int                       # scheduler tick counter
    t: float                        # tick timestamp (scheduler clock)
    queue_depth: int                # waiting requests after admission
    n_active: int                   # resident decode slots, all pools
    capacity: int                   # total decode slots, all pools
    batch_by_geometry: Dict[str, int]  # active slots per geometry bucket
    prefill_chunks: int             # prefill chunks streamed this tick
    dispatch_delta: int             # compiled calls issued this tick
    sa_level: int                   # sparsity rung after this tick
    pressure: float                 # LoadTracker queue-pressure signal
    prefix_device_bytes: int = 0    # prefix store occupancy, device tier
    prefix_host_bytes: int = 0      # prefix store occupancy, host tier
    events: Tuple[str, ...] = ()    # non-ok retirements: "status:rid"

    def as_dict(self) -> Dict[str, object]:
        d = self.__dict__.copy()
        d["batch_by_geometry"] = dict(self.batch_by_geometry)
        d["events"] = list(self.events)
        return d


class FlightRecorder:
    """Bounded ring of :class:`TickRecord` — the last ``capacity``
    scheduler ticks, oldest evicted first."""

    def __init__(self, capacity: int = 512):
        if capacity < 1:
            raise ValueError(
                f"FlightRecorder: capacity={capacity} must be >= 1 tick")
        self.capacity = int(capacity)
        self._ring: "deque[TickRecord]" = deque(maxlen=self.capacity)
        self.recorded = 0  # lifetime ticks seen (>= len(ring))

    def record(self, rec: TickRecord) -> None:
        self._ring.append(rec)
        self.recorded += 1

    def __len__(self) -> int:
        return len(self._ring)

    def dump(self) -> List[Dict[str, object]]:
        """The retained ticks, oldest first, as plain dicts (JSON-ready
        incident payload)."""
        return [r.as_dict() for r in self._ring]

    def last(self) -> Optional[TickRecord]:
        return self._ring[-1] if self._ring else None

    def clear(self) -> None:
        self._ring.clear()


def main(argv: Optional[List[str]] = None) -> int:
    """CLI validator: ``python -m repro.serve.telemetry metrics.prom``
    parses an exposition file and reports the metric census (exit 1 on
    malformed input) — the CI smoke's 'does the endpoint scrape' gate."""
    import sys
    args = sys.argv[1:] if argv is None else argv
    if len(args) != 1:
        print("usage: python -m repro.serve.telemetry <metrics.prom>",
              file=sys.stderr)
        return 2
    with open(args[0]) as f:
        text = f.read()
    try:
        samples = parse_prometheus_text(text)
    except ValueError as e:
        print(f"INVALID prometheus text: {e}", file=sys.stderr)
        return 1
    n = sum(len(v) for v in samples.values())
    print(f"ok: {len(samples)} metrics, {n} samples")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
