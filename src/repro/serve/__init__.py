from repro.serve.engine import (  # noqa: F401
    ChunkedPrefill,
    GenerationResult,
    KVStats,
    Request,
    ServeEngine,
    chunk_plan,
    kv_cache_bytes,
    kv_cache_stats,
    repack_caches,
    seed_caches,
    serve_batch,
)
from repro.serve import kv_cache  # noqa: F401
from repro.serve.scheduler import (  # noqa: F401
    ContinuousScheduler,
    FinishedRequest,
    RequestMetrics,
)
from repro.serve.slots import SlotPool  # noqa: F401
