from repro.serve.engine import (  # noqa: F401
    GenerationResult,
    Request,
    ServeEngine,
    repack_caches,
    serve_batch,
)
from repro.serve import kv_cache  # noqa: F401
