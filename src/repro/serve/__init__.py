from repro.serve.engine import (  # noqa: F401
    ChunkedPrefill,
    DrainResult,
    GenerationResult,
    KVStats,
    Request,
    ServeEngine,
    chunk_plan,
    kv_cache_bytes,
    kv_cache_stats,
    repack_caches,
    seed_caches,
    serve_batch,
    serve_batch_finished,
)
from repro.serve import kv_cache  # noqa: F401
from repro.serve.prefix_cache import (  # noqa: F401
    PrefixStore,
    PrefixStoreStats,
    Snapshot,
)
from repro.serve.scheduler import (  # noqa: F401
    ContinuousScheduler,
    FinishedRequest,
    RequestMetrics,
)
from repro.serve.slo import (  # noqa: F401
    LoadTracker,
    SHED_DROP_LOWEST,
    SHED_POLICIES,
    SHED_REJECT_NEWEST,
    SLOConfig,
    STATUS_CANCELLED,
    STATUS_FAILED,
    STATUS_OK,
    STATUS_SHED,
    STATUS_TIMEOUT,
    STATUSES,
)
from repro.serve.slots import SlotPool  # noqa: F401
from repro.serve.telemetry import (  # noqa: F401
    FlightRecorder,
    Histogram,
    MetricsRegistry,
    TickRecord,
    parse_prometheus_text,
    quantile,
    summarize,
)
from repro.serve.tracing import (  # noqa: F401
    PID_REQUESTS,
    PID_SCHEDULER,
    PID_SLOTS,
    SpanTracer,
    request_spans,
    validate_trace,
)
