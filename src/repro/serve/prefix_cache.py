"""Shared-prefix radix cache: chunk-boundary snapshot reuse for
admission (DESIGN.md §Prefix cache).

Production prompt traffic is dominated by shared prefixes — system
preambles, few-shot scaffolds, multi-turn histories — yet a cold
admission re-streams the whole prompt through the chunked prefill.
Flux makes prefix reuse unusually clean: prefix-only router pooling
(``routing_ctx="hard_prefix"``) means two requests sharing the first
``pool_size`` tokens share their *routing decision* and hence their
cache geometry, so a cached prefix state is reusable across requests
by construction; and because ring/Mamba state is part of the snapshot,
reuse stays exact at SA and SSM layers where token-granular paged-KV
block reuse (vLLM-style) cannot represent the state at all.

The store is a radix tree over token ids at **chunk-plan boundaries**:
every edge is exactly one full prefill chunk (``chunk`` tokens), so
any two prompts sharing k·chunk tokens share the first k nodes of a
path — these are precisely the boundaries where the chunked admission
(`engine.ChunkedPrefill`) has a complete, self-contained device state:
the per-layer decode-geometry cache list (FullKV / RingKV / LatentKV /
RingLatentKV slices with their ring ``positions``, Mamba ``(h,
conv_tail)``) plus the boundary's last-token logits and the frozen
routing pattern.  A node holds that state as an immutable
:class:`Snapshot`; matching a new prompt walks full-chunk edges and
returns the deepest snapshot-bearing node, turning prefill work from
O(prompt) into O(unique suffix).

Memory policy: snapshots are refcounted (``acquire``/``release`` pin a
node against eviction while an admission restores from it) and live in
two byte-budgeted tiers — a device tier under ``budget_bytes`` and an
optional host tier under ``host_budget_bytes``.  Going over the device
budget demotes the least-recently-used unpinned snapshot to host
(``jax.device_put`` to CPU) when the host tier is enabled, else drops
it; host overflow drops.  A hit on a host-resident node prefetches the
state back to device.  Evicted nodes stay in the tree as structural
pass-throughs so deeper snapshots remain reachable; fully empty leaves
are pruned.

The store holds one radix tree per *routing key*: router-driven
admissions share one tree (same weights ⇒ same prefix-pooled
decisions), while each ``routing_override`` pattern gets its own (a
forced pattern changes the state, not just the geometry).
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.serve import kv_cache as KC


def routing_key(override, sa_level: int = 0) -> Tuple:
    """Radix-tree namespace for an admission's routing source.

    Router-driven admissions (``override is None``) share one tree;
    every forced pattern gets its own — a snapshot taken under one
    override is never offered to a request running another (the
    routing-compatibility half of the match check; the other half,
    ``router.prefix_routing_reusable``, guards the router-driven tree).

    Router-driven trees are further scoped by the load-adaptive
    sparsity rung (``sa_level``, serve/slo.py): a rung change moves the
    FA-decision threshold, so decisions taken at one rung do not
    transfer to another — a warm snapshot must never hand a pressured
    admission the relaxed rung's pattern (or vice versa).  Overrides
    ignore the dial entirely, so their namespaces stay level-free.
    """
    if override is not None:
        return ("override", tuple(override))
    return ("router", int(sa_level))


def state_bytes(caches, logits) -> int:
    """Device bytes of one boundary state (cache pytree + logits)."""
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree.leaves((caches, logits)))


def snapshot_spec_bytes(cfg: ModelConfig, pattern, max_len: int) -> int:
    """Bytes of one boundary snapshot for ``pattern`` — from abstract
    shapes only (``eval_shape``), so config-time budget validation
    never allocates."""
    spec = jax.eval_shape(
        lambda: KC.init_decode_caches(cfg, pattern, 1, max_len))
    n = sum(leaf.size * jnp.dtype(leaf.dtype).itemsize
            for leaf in jax.tree.leaves(spec))
    return n + cfg.vocab_size * jnp.dtype(cfg.dtype).itemsize


@dataclass
class Snapshot:
    """Immutable admission state at one chunk boundary.

    ``caches`` is the B=1 decode-geometry per-layer cache list exactly
    as `ChunkedPrefill` carries it — restoring is therefore just a
    bitwise copy into fresh buffers (the engine's per-geometry restore
    jit) and streaming the uncovered suffix; no repacking, rescaling or
    re-routing happens on the hit path.
    """
    caches: Any                   # per-layer cache pytree, B=1
    logits: jax.Array             # (1, V) last-token logits at boundary
    pattern: Tuple[Any, ...]      # frozen per-layer routing pattern
    p_fa: Optional[np.ndarray]    # router probabilities (metrics only)
    boundary: int                 # prompt tokens covered
    nbytes: int                   # buffer bytes (device or host tier)


@dataclass
class _Node:
    """One radix node = one chunk boundary of some published prompt."""
    depth: int                    # tokens covered by the path to here
    parent: Optional["_Node"] = None
    edge: Optional[bytes] = None  # key in parent.children
    children: Dict[bytes, "_Node"] = field(default_factory=dict)
    snap: Optional[Snapshot] = None
    on_host: bool = False
    refs: int = 0                 # in-use pins; evictable iff 0


@dataclass
class PrefixStoreStats:
    device_bytes: int
    host_bytes: int
    snapshots: int
    nodes: int
    hits: int
    misses: int
    hit_tokens: int
    inserts: int
    demotions: int
    drops: int
    # per-tier byte high watermarks over the store's lifetime — the
    # memory ledger (serve/telemetry.py) reports residency peaks, not
    # just the instantaneous occupancy a scrape happens to see
    device_high_watermark: int = 0
    host_high_watermark: int = 0

    def as_dict(self) -> Dict[str, int]:
        return self.__dict__.copy()


class PrefixStore:
    """Refcounted, byte-budgeted radix store of chunk-boundary
    snapshots.  Host-side bookkeeping only — every device operation
    (snapshot copy, host offload, prefetch) is driven by the engine or
    by ``jax.device_put`` here; the store never traces anything."""

    def __init__(self, chunk: int, budget_bytes: int,
                 host_budget_bytes: int = 0):
        if chunk <= 0:
            raise ValueError(
                f"PrefixStore: chunk={chunk} must be positive — snapshots "
                f"are keyed at chunk-plan boundaries")
        if budget_bytes <= 0:
            raise ValueError(
                f"PrefixStore: budget_bytes={budget_bytes} must be "
                f"positive; to disable prefix caching leave the engine's "
                f"prefix_cache_mb unset instead")
        self.chunk = int(chunk)
        self.budget_bytes = int(budget_bytes)
        self.host_budget_bytes = int(host_budget_bytes)
        # optional telemetry hook (DESIGN.md §Observability): called
        # with an event name ("insert"/"demotion"/"drop"/"promotion";
        # the engine adds "hit"/"miss" where it counts them) so a
        # metrics registry can observe store churn without the store
        # importing telemetry.  None = no-op.
        self.on_event: Optional[Any] = None
        self._roots: Dict[Tuple, _Node] = {}
        # LRU over snapshot-bearing nodes (both tiers), least recent first
        self._lru: "OrderedDict[int, _Node]" = OrderedDict()
        self._host_dev = None  # lazy jax.devices("cpu")[0]
        self.device_bytes = 0
        self.host_bytes = 0
        self.device_high_watermark = 0
        self.host_high_watermark = 0
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0
        self.inserts = 0
        self.demotions = 0
        self.drops = 0

    # -- keys ----------------------------------------------------------------
    def _edge(self, toks: np.ndarray, depth: int) -> bytes:
        return np.ascontiguousarray(
            toks[depth:depth + self.chunk], np.int32).tobytes()

    def _touch(self, node: _Node) -> None:
        self._lru.move_to_end(id(node))

    def _note_watermarks(self) -> None:
        """Bump the per-tier high watermarks; called after any byte
        increase (insert, demotion, promotion)."""
        if self.device_bytes > self.device_high_watermark:
            self.device_high_watermark = self.device_bytes
        if self.host_bytes > self.host_high_watermark:
            self.host_high_watermark = self.host_bytes

    # -- lookup --------------------------------------------------------------
    def match(self, tokens, key: Tuple) -> Optional[_Node]:
        """Deepest snapshot-bearing node whose path is a prefix of
        ``tokens`` at full-chunk boundaries (longest-prefix match).
        Bumps the returned node's LRU position; hit/miss counters are
        the caller's (the engine distinguishes a miss from a request
        that opted out of reuse)."""
        toks = np.asarray(tokens)
        node = self._roots.get(key)
        best = None
        depth = 0
        while node is not None and depth + self.chunk <= toks.size:
            node = node.children.get(self._edge(toks, depth))
            depth += self.chunk
            if node is not None and node.snap is not None:
                best = node
        if best is not None:
            self._touch(best)
        return best

    def covered(self, tokens, boundary: int, key: Tuple) -> bool:
        """True iff a snapshot already exists at exactly ``boundary``
        for this prefix — publication dedupe (bumps its LRU slot)."""
        toks = np.asarray(tokens)
        node = self._roots.get(key)
        depth = 0
        while node is not None and depth < boundary:
            node = node.children.get(self._edge(toks, depth))
            depth += self.chunk
        if node is not None and node.snap is not None:
            self._touch(node)
            return True
        return False

    # -- refcounting ---------------------------------------------------------
    def acquire(self, node: _Node) -> None:
        """Pin ``node`` against eviction (an admission is restoring
        from it, or a publication is mid-insert)."""
        node.refs += 1

    def release(self, node: _Node) -> None:
        if node.refs <= 0:
            raise RuntimeError(
                "PrefixStore.release: refcount underflow — release() "
                "without a matching acquire(); node refcounts must never "
                "go negative")
        node.refs -= 1

    # -- insertion -----------------------------------------------------------
    def insert(self, tokens, snap: Snapshot, key: Tuple) -> _Node:
        """Attach ``snap`` at its boundary, creating the path as
        needed, then enforce the byte budgets.  The snapshot's buffers
        must already be the store's own copies (the engine's restore
        jit made them) — donation of the live admission buffers can
        never invalidate them."""
        boundary = snap.boundary
        if boundary <= 0 or boundary % self.chunk:
            raise ValueError(
                f"PrefixStore.insert: boundary={boundary} is not a "
                f"positive multiple of chunk={self.chunk} — snapshots "
                f"exist only at full-chunk plan boundaries")
        toks = np.asarray(tokens)
        if boundary > toks.size:
            raise ValueError(
                f"PrefixStore.insert: boundary={boundary} exceeds the "
                f"prompt length {toks.size}")
        node = self._roots.setdefault(key, _Node(depth=0))
        depth = 0
        while depth < boundary:
            ek = self._edge(toks, depth)
            nxt = node.children.get(ek)
            if nxt is None:
                nxt = _Node(depth=depth + self.chunk, parent=node, edge=ek)
                node.children[ek] = nxt
            node = nxt
            depth += self.chunk
        if node.snap is not None:  # already covered — keep the older copy
            self._touch(node)
            return node
        node.snap = snap
        node.on_host = False
        self.device_bytes += snap.nbytes
        self._note_watermarks()
        self.inserts += 1
        if self.on_event is not None:
            self.on_event("insert")
        self._lru[id(node)] = node
        self._touch(node)
        self.enforce_budget()
        return node

    # -- eviction ------------------------------------------------------------
    def _lru_victim(self, on_host: bool) -> Optional[_Node]:
        for node in self._lru.values():
            if node.on_host is on_host and node.refs == 0:
                return node
        return None

    def _host_device(self):
        if self._host_dev is None:
            self._host_dev = jax.devices("cpu")[0]
        return self._host_dev

    def _demote(self, node: _Node) -> None:
        """Device → host: ``jax.device_put`` the snapshot buffers to
        CPU, then hold them as numpy views.  The transfer is
        bit-identical, so a later hit restores the exact boundary
        state; holding *numpy* (not committed-to-CPU jax arrays)
        matters because committed inputs would thread a distinct
        sharding through the restore jit and on into the decode jit,
        silently doubling the per-geometry executable count."""
        snap = node.snap
        caches, logits = jax.device_put((snap.caches, snap.logits),
                                        self._host_device())
        caches, logits = jax.tree.map(np.asarray, (caches, logits))
        node.snap = Snapshot(caches=caches, logits=logits,
                             pattern=snap.pattern, p_fa=snap.p_fa,
                             boundary=snap.boundary, nbytes=snap.nbytes)
        node.on_host = True
        self.device_bytes -= snap.nbytes
        self.host_bytes += snap.nbytes
        self._note_watermarks()
        self.demotions += 1
        if self.on_event is not None:
            self.on_event("demotion")

    def _drop(self, node: _Node) -> None:
        nbytes = node.snap.nbytes
        if node.on_host:
            self.host_bytes -= nbytes
        else:
            self.device_bytes -= nbytes
        node.snap = None
        node.on_host = False
        self._lru.pop(id(node), None)
        self.drops += 1
        if self.on_event is not None:
            self.on_event("drop")
        # prune structural leaves so dropped paths don't accumulate
        while (node.parent is not None and not node.children
               and node.snap is None and node.refs == 0):
            node.parent.children.pop(node.edge, None)
            node = node.parent

    def enforce_budget(self) -> None:
        """LRU-evict until both tiers fit their budgets; pinned nodes
        (refs > 0) are never touched, so a burst of pins may hold the
        store over budget until they release."""
        while self.device_bytes > self.budget_bytes:
            victim = self._lru_victim(on_host=False)
            if victim is None:
                break  # everything device-resident is pinned
            if self.host_budget_bytes > 0:
                self._demote(victim)
            else:
                self._drop(victim)
        while self.host_bytes > self.host_budget_bytes:
            victim = self._lru_victim(on_host=True)
            if victim is None:
                break
            self._drop(victim)

    def promote(self, node: _Node, caches, logits: jax.Array) -> None:
        """Host → device: adopt ``caches``/``logits`` — the device
        copies a hit just prefetched — as the node's snapshot, so the
        next hit on this (evidently warm) prefix skips the
        host-to-device transfer.  The budgets re-settle afterwards: a
        colder device snapshot may demote in its place."""
        snap = node.snap
        if snap is None or not node.on_host:
            return
        node.snap = Snapshot(caches=caches, logits=logits,
                             pattern=snap.pattern, p_fa=snap.p_fa,
                             boundary=snap.boundary, nbytes=snap.nbytes)
        node.on_host = False
        self.host_bytes -= snap.nbytes
        self.device_bytes += snap.nbytes
        self._note_watermarks()
        if self.on_event is not None:
            self.on_event("promotion")
        self._touch(node)
        self.enforce_budget()

    def offload_all(self) -> int:
        """Demote every unpinned device-resident snapshot to the host
        tier (ops/tests hook: free device HBM without losing warmth).
        Returns the number demoted.  Requires the host tier."""
        if self.host_budget_bytes <= 0:
            raise ValueError(
                "PrefixStore.offload_all: host tier disabled "
                "(host_budget_bytes=0); set the engine's "
                "prefix_cache_host_mb to enable host offload")
        n = 0
        for node in list(self._lru.values()):
            if not node.on_host and node.refs == 0:
                self._demote(node)
                n += 1
        self.enforce_budget()
        return n

    # -- introspection -------------------------------------------------------
    def _count_nodes(self) -> int:
        total = 0
        stack = list(self._roots.values())
        while stack:
            n = stack.pop()
            total += 1
            stack.extend(n.children.values())
        return total

    def stats(self) -> PrefixStoreStats:
        return PrefixStoreStats(
            device_bytes=self.device_bytes, host_bytes=self.host_bytes,
            snapshots=len(self._lru), nodes=self._count_nodes(),
            hits=self.hits, misses=self.misses, hit_tokens=self.hit_tokens,
            inserts=self.inserts, demotions=self.demotions,
            drops=self.drops,
            device_high_watermark=self.device_high_watermark,
            host_high_watermark=self.host_high_watermark)
