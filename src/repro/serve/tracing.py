"""Request-span tracing with Chrome-trace / Perfetto JSON export
(DESIGN.md §Observability).

The scheduler records each request's lifecycle as host-side span
events in the **scheduler's clock domain** (the injectable ``clock``
callable — ``time.monotonic`` in production, a virtual clock in
tests):

  submit → queue → admit → per-prefill-chunk → per-decode-tick slot
  residency → retire (ok / timeout / shed / cancelled / failed)

``ServeEngine.export_trace(path)`` serializes the run as Chrome Trace
Event Format JSON (the ``traceEvents`` array form), which
chrome://tracing and https://ui.perfetto.dev open directly.  Track
layout:

  pid 1 "requests"  — one thread per request (tid = rid): the request's
      lifetime span (named ``req<rid>``, args carry status/metrics),
      queue/prefill/decode phase sub-spans, per-chunk prefill spans,
      and instants for submit / preempt / retire.
  pid 2 "slots"     — one thread per (geometry bucket, slot): a span
      per decode tick labeled with the resident rid, so a drain
      renders as the slots × ticks occupancy grid.
  pid 3 "scheduler" — per-tick spans and counter tracks (queue depth,
      active slots, sa_level, load pressure).

Everything here is host-side bookkeeping: emitting an event is a dict
append, timestamps come from the scheduler clock, and nothing imports
jax — tracing can never add a device sync or a compiled executable.
The event buffer is bounded (``max_events``; overflow counts into
``dropped`` instead of growing without bound).

``python -m repro.serve.tracing trace.json`` validates an exported
trace against the schema check used by the tests and the CI smoke.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

# fixed process ids of the three tracks (stable across exports so
# Perfetto queries / saved UI states keep working)
PID_REQUESTS = 1
PID_SLOTS = 2
PID_SCHEDULER = 3

_PROCESS_NAMES = {PID_REQUESTS: "requests", PID_SLOTS: "slots",
                  PID_SCHEDULER: "scheduler"}

# event phases this tracer emits (and the validator accepts)
_PHASES = ("X", "i", "I", "C", "M", "B", "E")


class SpanTracer:
    """Bounded host-side trace event buffer.

    Timestamps are seconds in the caller's clock domain; the tracer
    converts to the microseconds Chrome Trace Format expects at emit
    time.  ``complete``/``instant``/``counter`` are the only emitters
    the serving stack uses — complete ("X") events carry their duration
    inline, so no begin/end pairing state survives a crash-truncated
    export."""

    def __init__(self, max_events: int = 200_000):
        if max_events < 1:
            raise ValueError(
                f"SpanTracer: max_events={max_events} must be >= 1")
        self.max_events = int(max_events)
        self.events: List[Dict] = []
        self.dropped = 0
        self._named_threads: set = set()
        for pid, name in _PROCESS_NAMES.items():
            self._meta("process_name", pid, 0, {"name": name})
            self._meta("process_sort_index", pid, 0, {"sort_index": pid})

    # -- low-level emit ------------------------------------------------------
    def _emit(self, ev: Dict) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append(ev)

    def _meta(self, name: str, pid: int, tid: int, args: Dict) -> None:
        # metadata events bypass the budget: they are O(#tracks), and a
        # truncated trace with unnamed tracks is much harder to read
        self.events.append({"name": name, "ph": "M", "pid": pid,
                            "tid": tid, "args": args})

    def name_thread(self, pid: int, tid: int, name: str,
                    sort_index: Optional[int] = None) -> None:
        """Label a track once (idempotent per (pid, tid))."""
        key = (pid, tid)
        if key in self._named_threads:
            return
        self._named_threads.add(key)
        self._meta("thread_name", pid, tid, {"name": name})
        if sort_index is not None:
            self._meta("thread_sort_index", pid, tid,
                       {"sort_index": sort_index})

    # -- emitters ------------------------------------------------------------
    def complete(self, name: str, pid: int, tid: int, t0: float, t1: float,
                 cat: str = "serve", args: Optional[Dict] = None) -> None:
        """A span [t0, t1] (seconds, clock domain) as one "X" event."""
        ev = {"name": name, "cat": cat, "ph": "X", "pid": pid, "tid": tid,
              "ts": t0 * 1e6, "dur": max(t1 - t0, 0.0) * 1e6}
        if args:
            ev["args"] = args
        self._emit(ev)

    def instant(self, name: str, pid: int, tid: int, t: float,
                cat: str = "serve", args: Optional[Dict] = None) -> None:
        ev = {"name": name, "cat": cat, "ph": "i", "pid": pid, "tid": tid,
              "ts": t * 1e6, "s": "t"}
        if args:
            ev["args"] = args
        self._emit(ev)

    def counter(self, name: str, t: float, values: Dict[str, float],
                pid: int = PID_SCHEDULER) -> None:
        """A counter sample — Perfetto renders these as step plots."""
        self._emit({"name": name, "cat": "serve", "ph": "C", "pid": pid,
                    "tid": 0, "ts": t * 1e6,
                    "args": {k: float(v) for k, v in values.items()}})

    # -- export --------------------------------------------------------------
    def to_json(self) -> Dict:
        return {
            "traceEvents": list(self.events),
            "displayTimeUnit": "ms",
            "otherData": {
                "clock_domain": "scheduler clock (seconds → µs)",
                "dropped_events": self.dropped,
            },
        }

    def export(self, path: str) -> None:
        """Write the trace as Perfetto-loadable JSON."""
        with open(path, "w") as f:
            json.dump(self.to_json(), f)


# ---------------------------------------------------------------------------
# Schema validation (tests + CI smoke)
# ---------------------------------------------------------------------------


def validate_trace(obj) -> Dict[str, int]:
    """Check ``obj`` (a parsed trace JSON) against the Chrome Trace
    Event Format subset this tracer emits.  Raises ``ValueError`` on
    the first violation; returns a {phase: count} census on success —
    the tests assert on it, and the CI smoke prints it."""
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError(
            "trace must be a JSON object with a 'traceEvents' array "
            "(the Chrome Trace Event Format object form)")
    events = obj["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    census: Dict[str, int] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"traceEvents[{i}] is not an object")
        ph = ev.get("ph")
        if ph not in _PHASES:
            raise ValueError(
                f"traceEvents[{i}]: unknown or missing phase {ph!r} "
                f"(expected one of {_PHASES})")
        for key in ("pid", "tid"):
            if not isinstance(ev.get(key), int):
                raise ValueError(
                    f"traceEvents[{i}] ({ph}): {key!r} must be an int, "
                    f"got {ev.get(key)!r}")
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            raise ValueError(
                f"traceEvents[{i}] ({ph}): missing event name")
        if ph != "M":
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)):
                raise ValueError(
                    f"traceEvents[{i}] ({ph} {ev['name']!r}): 'ts' must "
                    f"be a number (µs), got {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(
                    f"traceEvents[{i}] (X {ev['name']!r}): 'dur' must be "
                    f"a non-negative number (µs), got {dur!r}")
        if ph == "C" and not isinstance(ev.get("args"), dict):
            raise ValueError(
                f"traceEvents[{i}] (C {ev['name']!r}): counter events "
                f"need an 'args' value mapping")
        census[ph] = census.get(ph, 0) + 1
    return census


def request_spans(obj) -> Dict[int, Dict]:
    """{rid: lifetime-span event} for every request track in a trace —
    the coverage check behind 'every request in DrainResult has a
    submit→retire span'."""
    out: Dict[int, Dict] = {}
    for ev in obj.get("traceEvents", ()):
        if (ev.get("ph") == "X" and ev.get("pid") == PID_REQUESTS
                and str(ev.get("name", "")).startswith("req")):
            out[int(ev["tid"])] = ev
    return out


def main(argv: Optional[List[str]] = None) -> int:
    """CLI validator: ``python -m repro.serve.tracing trace.json``."""
    import sys
    args = sys.argv[1:] if argv is None else argv
    if len(args) != 1:
        print("usage: python -m repro.serve.tracing <trace.json>",
              file=sys.stderr)
        return 2
    with open(args[0]) as f:
        obj = json.load(f)
    try:
        census = validate_trace(obj)
    except ValueError as e:
        print(f"INVALID trace: {e}", file=sys.stderr)
        return 1
    spans = request_spans(obj)
    probed = sum(1 for ev in spans.values()
                 if "fidelity" in ev.get("args", {}))
    print(f"ok: {sum(census.values())} events {census}; "
          f"{len(spans)} request lifetime spans ({probed} with fidelity)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
