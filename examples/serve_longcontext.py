"""Serve a small model with batched mixed-task requests: the engine
routes each bucket at prefill, keeps FA layers' full KV and SA layers'
sink+local rings, and reports the paper's efficiency metrics.

    PYTHONPATH=src python examples/serve_longcontext.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config, smoke_variant  # noqa: E402
from repro.data import SyntheticTasks  # noqa: E402
from repro.models import model as MD  # noqa: E402
from repro.serve import Request, ServeEngine, serve_batch  # noqa: E402


def main() -> None:
    cfg = smoke_variant(get_config("gemma3-12b"))  # 5:1 local:global
    params = MD.init_params(jax.random.key(0), cfg)
    gen = SyntheticTasks(cfg.vocab_size, seed=0)
    rng = np.random.default_rng(0)

    # a mixed batch: retrieval-heavy and holistic prompts
    reqs = []
    for rid in range(6):
        task = "needle" if rid % 2 == 0 else "markov"
        b = gen.batch(rng, task, 1, 128)
        reqs.append(Request(rid=rid, tokens=b.tokens[0], n_steps=12))

    for sparse in (True, False):
        engine = ServeEngine(params, cfg, max_len=160,
                             sparse_decode=sparse)
        t0 = time.time()
        results = serve_batch(engine, reqs)
        dt = time.time() - t0
        # one representative generation for cache stats
        probe = engine.generate(reqs[0].tokens[None], 2)
        mode = "sparse-decode" if sparse else "dense-decode"
        routing = "".join("F" if p == "fa" else "S" if p == "sa" else "."
                          for p in probe.routing)
        print(f"[{mode:13s}] {len(results)} requests in {dt:5.2f}s | "
              f"KV={probe.kv_bytes / 1e6:6.2f} MB | routing={routing}")
    print("(gemma3: '.' = sliding-window local layers — already sparse, "
          "only the 1-in-6 global layers are flux-routed)")


if __name__ == "__main__":
    main()
