"""Quickstart: build a flux-routed model, route a prompt, generate.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config, smoke_variant  # noqa: E402
from repro.data import SyntheticTasks  # noqa: E402
from repro.models import model as MD  # noqa: E402
from repro.serve import ServeEngine  # noqa: E402


def main() -> None:
    # 1. Any assigned architecture is a config away (--arch elsewhere);
    #    the smoke variant is CPU-sized but structurally identical.
    cfg = smoke_variant(get_config("phi3-mini-3.8b"))
    print(f"arch={cfg.name}: {cfg.num_layers} layers, "
          f"{len(cfg.routable_layers())} flux-routable, "
          f"SA mode={cfg.flux.sa_mode} "
          f"(sink={cfg.flux.sink}, local={cfg.flux.local})")

    # 2. Init params (random here; see train_router.py for training).
    params = MD.init_params(jax.random.key(0), cfg)

    # 3. One engine = prefill → route once → sparse decode (paper §3.3).
    engine = ServeEngine(params, cfg, max_len=160)
    prompts = SyntheticTasks(cfg.vocab_size, seed=0)
    batch = prompts.batch(np.random.default_rng(0), "needle", 2, 128)

    out = engine.generate(batch.tokens, n_steps=8)
    routing = "".join("F" if p == "fa" else "S" if p == "sa" else "."
                      for p in out.routing)
    print(f"routing (F=full, S=sparse): {routing}")
    print(f"Ω_MSR={out.msr:.2f}  decode KV={out.kv_bytes / 1e6:.2f} MB")
    print(f"generated tokens:\n{out.tokens}")

    # 4. The same model under soft routing (training mode, Eq. 5):
    fwd = MD.forward_train(params, cfg, jax.numpy.asarray(batch.tokens),
                           rng=jax.random.key(1), tau=2.0, remat=False)
    print(f"soft routing weights r_soft (B, n_routed):\n"
          f"{np.asarray(fwd.r_soft).round(3)}")


if __name__ == "__main__":
    main()
