"""Shared-prefix serving demo: every request opens with the same
system prompt, and the radix prefix cache turns all but the first
admission into O(unique suffix) work.

The first request streams its whole prompt through the chunked
prefill, publishing a snapshot of the full per-layer cache state at
every chunk boundary it crosses.  Later requests longest-prefix-match
the radix tree, restore the deepest snapshot (one compiled copy), and
stream only their unique suffix — the shared system prompt never runs
through the model again.  Because the snapshot carries ring positions
and Mamba state, hit-path continuations are *bitwise* identical to
cold admissions (asserted below).

    PYTHONPATH=src python examples/serve_shared_prefix.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config, smoke_variant  # noqa: E402
from repro.models import model as MD  # noqa: E402
from repro.serve import Request, ServeEngine  # noqa: E402

CHUNK = 16
SYSTEM_PROMPT_CHUNKS = 3  # 48 shared tokens ≈ 75% of every prompt


def main() -> None:
    cfg = smoke_variant(get_config("phi3-mini-3.8b"))
    params = MD.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)

    system = rng.integers(0, cfg.vocab_size,
                          size=SYSTEM_PROMPT_CHUNKS * CHUNK
                          ).astype(np.int32)
    reqs = [Request(rid=rid,
                    tokens=np.concatenate([
                        system,
                        rng.integers(0, cfg.vocab_size, size=CHUNK
                                     ).astype(np.int32)]),
                    n_steps=8)
            for rid in range(6)]

    def serve(name: str, eng: ServeEngine) -> dict:
        for r in reqs:
            eng.submit(r)
        t0 = time.time()
        out = eng.drain()
        s = out.summary
        print(f"[{name:12s}] {s['n_requests']} requests in "
              f"{time.time() - t0:5.2f}s | ttft p50 "
              f"{s['ttft_p50_s'] * 1e3:6.1f}ms | warm prompt tokens "
              f"{s['prefix_hit_tokens']}/{s['prompt_tokens']} "
              f"({s['prefix_hit_fraction']:.0%}) | store device="
              f"{s['prefix_device_bytes']}B host={s['prefix_host_bytes']}B")
        return {r: out[r].tokens for r in out}

    cold = serve("cold", ServeEngine(params, cfg, max_len=96,
                                     prefill_chunk=CHUNK))
    eng = ServeEngine(params, cfg, max_len=96, prefill_chunk=CHUNK,
                      prefix_cache_mb=64, prefix_cache_host_mb=64)
    serve("warming", eng)   # first drain builds the radix tree
    warm = serve("warm", eng)

    st = eng.prefix_store.stats()
    print(f"store: {st.hits} hits / {st.misses} misses, "
          f"{st.hit_tokens} prompt tokens served from snapshots, "
          f"{st.snapshots} snapshots over {st.nodes} radix nodes")
    assert all(np.array_equal(cold[r], warm[r]) for r in cold)
    print("hit-path continuations are bitwise-equal to cold admissions")

    # host offload: park every snapshot in CPU memory, serve again —
    # hits prefetch back and stay exact
    eng.prefix_store.offload_all()
    again = serve("host-tier", eng)
    assert all(np.array_equal(cold[r], again[r]) for r in cold)
    print("after evict-to-host, hits prefetch back bitwise-equal")


if __name__ == "__main__":
    main()
