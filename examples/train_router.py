"""End-to-end driver: pretrain a ~100M backbone on the synthetic
long-context mixture, then train the Flux Layer Router (frozen
backbone, Lagrangian budget, temperature annealing) for a few hundred
steps — the paper's §4.1 recipe at CPU scale.

    PYTHONPATH=src python examples/train_router.py [--fast]

--fast shrinks to smoke scale (~1 minute); the default (~100M params)
takes a while on CPU but exercises the same code path that
launch/dryrun.py lowers for the 256-chip mesh.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config, smoke_variant  # noqa: E402
from repro.data import mixture_iterator  # noqa: E402
from repro.models import model as MD  # noqa: E402
from repro.train import (PretrainTrainer, RouterTrainer,  # noqa: E402
                         checkpoint)
from benchmarks.common import eval_accuracy, live_msr  # noqa: E402


def hundred_m_cfg():
    """~100M-param phi3-family config (8L, d=768) with paper flux
    geometry scaled to the training length."""
    base = get_config("phi3-mini-3.8b")
    return base.replace(
        num_layers=8, d_model=768, num_heads=12, num_kv_heads=12,
        head_dim=64, d_ff=2048, vocab_size=2048,
        dtype=jax.numpy.float32, param_dtype=jax.numpy.float32,
        flux=base.flux.replace(sink=8, local=64, pool_size=16,
                               router_hidden=64))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--pretrain-steps", type=int, default=300)
    ap.add_argument("--router-steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    if args.fast:
        cfg = smoke_variant(get_config("phi3-mini-3.8b")).replace(
            vocab_size=64)
        args.pretrain_steps, args.router_steps = 400, 60
        args.seq = 96
        args.batch = 16
    else:
        cfg = hundred_m_cfg()
    n_params = cfg.param_count()
    print(f"config: {cfg.num_layers}L d={cfg.d_model} "
          f"({n_params / 1e6:.0f}M params)")

    params = MD.init_params(jax.random.key(0), cfg)
    data = mixture_iterator(cfg.vocab_size, args.batch, args.seq, seed=0,
                            weights={"markov": 0.5, "needle": 0.35,
                                     "multihop": 0.15})

    print("== phase 1: backbone pretraining (substitute for the "
          "pretrained Qwen/Llama checkpoints) ==")
    pt = PretrainTrainer(cfg, total_steps=args.pretrain_steps, lr=2e-3)
    st = pt.init(params)
    st, _ = pt.run(st, data, args.pretrain_steps, log_every=50)
    params = st["params"]

    print("== phase 2: Layer-Router training (backbone FROZEN; "
          "λ ascent; τ annealing — paper Eq. 6) ==")
    rt = RouterTrainer(cfg, total_steps=args.router_steps)
    state = rt.init(params, jax.random.key(1))
    state, hist = rt.run(state, data, args.router_steps, log_every=25)
    params = rt.params(state)

    print("== phase 3: evaluation ==")
    acc_fa = eval_accuracy(cfg, params, "needle", routing_ctx="fa_only",
                           seq=args.seq)
    acc_fx = eval_accuracy(cfg, params, "needle", routing_ctx="hard",
                           seq=args.seq)
    acc_sa = eval_accuracy(cfg, params, "needle",
                           pattern=np.zeros(cfg.num_layers, np.int64),
                           seq=args.seq)
    msr_r = live_msr(cfg, params, "needle", seq=args.seq)
    msr_h = live_msr(cfg, params, "markov", seq=args.seq)
    print(f"needle acc: FA={acc_fa:.3f} flux={acc_fx:.3f} "
          f"all-SA={acc_sa:.3f}")
    print(f"router Ω_MSR: retrieval={msr_r:.2f} holistic={msr_h:.2f} "
          f"(holistic should sparsify more)")

    os.makedirs("artifacts/train", exist_ok=True)
    ck = "artifacts/train/example_router.msgpack"
    checkpoint.save(ck, params)
    print(f"checkpoint: {ck}")


if __name__ == "__main__":
    main()
