"""Continuous batching demo: mixed-length requests stream through the
slot-pool scheduler while the same traffic serializes under the
batch-synchronous frontend.

Each request is prefilled once (the Layer Router fires per request),
repacked to its routed cache geometry, and packed into a slot of the
matching geometry bucket; every tick decodes one chunk for all resident
requests of a bucket in a single compiled call.

    PYTHONPATH=src python examples/serve_continuous.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config, smoke_variant  # noqa: E402
from repro.data import SyntheticTasks  # noqa: E402
from repro.models import model as MD  # noqa: E402
from repro.serve import Request, ServeEngine, serve_batch  # noqa: E402


def main() -> None:
    cfg = smoke_variant(get_config("phi3-mini-3.8b"))
    params = MD.init_params(jax.random.key(0), cfg)
    gen = SyntheticTasks(cfg.vocab_size, seed=0)
    rng = np.random.default_rng(0)

    # mixed prompt lengths + a latency-sensitive high-priority straggler
    reqs = []
    for rid, plen in enumerate((32, 48, 64, 32, 48, 64)):
        task = "needle" if rid % 2 == 0 else "markov"
        b = gen.batch(rng, task, 1, plen)
        reqs.append(Request(rid=rid, tokens=b.tokens[0], n_steps=12))
    urgent = Request(rid=99, tokens=gen.batch(rng, "needle", 1, 32
                                              ).tokens[0],
                     n_steps=4, priority=5)

    # --- batch-synchronous baseline -----------------------------------
    eng_b = ServeEngine(params, cfg, max_len=96)
    t0 = time.time()
    serve_batch(eng_b, reqs + [urgent])
    print(f"[serve_batch ] 7 requests in {time.time() - t0:5.2f}s "
          f"(buckets run to completion; the urgent request waits "
          f"for its bucket's turn)")

    # --- continuous batching ------------------------------------------
    eng = ServeEngine(params, cfg, max_len=96)
    sched = eng.scheduler(slots_per_bucket=2, chunk=4)
    t0 = time.time()
    for r in reqs:
        eng.submit(r)
    eng.step()              # pools fill; first chunks decode
    eng.submit(urgent)      # arrives late, preempts a low-priority slot
    done = eng.drain()
    wall = time.time() - t0
    print(f"[continuous  ] 7 requests in {wall:5.2f}s | "
          f"geometry buckets={sched.n_geometries()} "
          f"decode executables={eng.decode_cache_size()}")
    for rid in sorted(done):
        m = done[rid].metrics
        mark = " <- priority 5, preempted its way in" if rid == 99 else ""
        print(f"  req {rid:2d}: prompt={m.prompt_len:3d} "
              f"tokens={m.n_generated:3d} ttft={m.ttft:6.3f}s "
              f"queue={m.queue_delay:6.3f}s{mark}")


if __name__ == "__main__":
    main()
