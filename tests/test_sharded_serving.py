"""Tensor-parallel pooled serving (DESIGN.md §Distributed serving).

The load-bearing guarantees of the mesh path:
  1. pooled greedy decode on a (1, N) mesh is token-identical to the
     single-device scheduler for every cache kind (FullKV / RingKV /
     LatentKV / Mamba across phi3 / jamba / deepseek), through
     preemption churn and prefix-cache warm restores;
  2. the executable guard holds per-(geometry, mesh): committed
     shardings must not split jit entries, so admission/retire/
     preemption churn on a mesh adds ZERO extra decode executables;
  3. the per-step decode collectives are activation-sized (O(H·D) /
     O(d_model) per token), never cache-sized (O(S·D)) — asserted via
     the hlo_costs analytic on the lowered decode scan;
  4. mesh=None stays bitwise- and dispatch-count-identical to an
     engine constructed without the kwarg (the mesh path is purely
     additive).

Mesh tests skip below 2 devices: CI runs them under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.launch import hlo_costs as HL
from repro.launch.mesh import make_debug_mesh
from repro.models import model as MD
from repro.serve import Request, ServeEngine

ARCHS = ["phi3-mini-3.8b", "jamba-1.5-large-398b", "deepseek-v2-236b"]

needs_mesh = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >= 2 devices (XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")


def _setup(arch):
    cfg = smoke_variant(get_config(arch))
    params = MD.init_params(jax.random.key(0), cfg)
    return cfg, params


def _mixed_requests(cfg, n, seed=0, n_steps=7, lens=(20, 28, 36), **kw):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab_size,
                                        size=lens[i % len(lens)]
                                        ).astype(np.int32),
                    n_steps=n_steps, **kw)
            for i in range(n)]


def _patterns3(cfg):
    kinds = cfg.layer_kinds
    fa = tuple("fa" if k == "attn" else None for k in kinds)
    sa = tuple("sa" if k == "attn" else None for k in kinds)
    flip, mixed = True, []
    for k in kinds:
        mixed.append(("fa" if flip else "sa") if k == "attn" else None)
        flip = not flip if k == "attn" else flip
    return [fa, sa, tuple(mixed)]


def _drain(engine, reqs, **sched_kw):
    engine.scheduler(**sched_kw)
    for r in reqs:
        engine.submit(r)
    return engine.drain()


# ---------------------------------------------------------------------------
# Token parity: mesh vs single-device pooled drain
# ---------------------------------------------------------------------------

@needs_mesh
@pytest.mark.parametrize("arch", ARCHS)
def test_mesh_pooled_drain_matches_single_device(arch):
    cfg, params = _setup(arch)
    mesh = make_debug_mesh(1, 2)
    eng = ServeEngine(params, cfg, max_len=64, mesh=mesh)
    out = _drain(eng, _mixed_requests(cfg, 6),
                 slots_per_bucket=3, chunk=4)
    ref = _drain(ServeEngine(params, cfg, max_len=64),
                 _mixed_requests(cfg, 6), slots_per_bucket=3, chunk=4)
    assert sorted(out) == sorted(ref)
    for rid in ref:
        assert np.array_equal(out[rid].tokens, ref[rid].tokens), rid
        assert out[rid].routing == ref[rid].routing
    # the guard's mesh half: churn on the mesh added no executables
    # beyond the geometries served
    sched = eng._scheduler
    assert eng.decode_cache_size() <= sched.n_geometries()
    eng._check_executable_guard()


@needs_mesh
def test_mesh_executable_guard_across_preemption_churn():
    """Admit/retire/preempt over 3 geometries on the mesh: the decode
    jit cache must end ≤ #geometries (committed shardings must not
    split entries), and every preempted request must still produce the
    tokens of an uninterrupted single-device generate."""
    cfg, params = _setup("phi3-mini-3.8b")
    mesh = make_debug_mesh(1, 2)
    patterns = _patterns3(cfg)
    rng = np.random.default_rng(4)
    eng = ServeEngine(params, cfg, max_len=64, mesh=mesh)
    sched = eng.scheduler(slots_per_bucket=1, chunk=2,
                          prefill_chunks_per_tick=12)
    rid, done, reqs = itertools.count(), {}, {}
    for wave, prio in enumerate((0, 1, 2)):
        for p in patterns:
            i = next(rid)
            toks = rng.integers(0, cfg.vocab_size,
                                size=20 + 4 * wave).astype(np.int32)
            reqs[i] = (toks, p)
            eng.submit(Request(rid=i, tokens=toks, n_steps=6,
                               priority=prio, routing_override=p))
        for f in sched.tick():
            done[f.rid] = f
    for f in sched.drain().values():
        done[f.rid] = f
    assert len(done) == 9
    assert any(f.metrics.preemptions > 0 for f in done.values())
    assert sched.n_geometries() == 3
    assert eng.decode_cache_size() <= 3
    eng._check_executable_guard()
    ref = ServeEngine(params, cfg, max_len=64)
    for i, (toks, p) in reqs.items():
        gen = ref.generate(toks[None], 6, routing_override=p)
        assert np.array_equal(done[i].tokens, gen.tokens[0]), i


@needs_mesh
@pytest.mark.parametrize("arch", ARCHS)
def test_mesh_prefix_warm_restore_matches_cold(arch):
    """Snapshot publish/restore must round-trip through the committed
    shardings: a warm prefix-cache admission on the mesh must be
    token-identical to the cold chunked path, and the restore must not
    mint extra executables (restore-path and fresh-prefill state commit
    to the same pool shardings before every consumer jit)."""
    cfg, params = _setup(arch)
    mesh = make_debug_mesh(1, 2)
    rng = np.random.default_rng(0)
    prefix = rng.integers(0, cfg.vocab_size, size=32).astype(np.int32)
    tails = [rng.integers(0, cfg.vocab_size, size=t).astype(np.int32)
             for t in (16, 13)]
    prompts = [np.concatenate([prefix, t]) for t in tails]
    cold = ServeEngine(params, cfg, max_len=80, prefill_chunk=16)
    refs = [cold.generate(p[None], 6) for p in prompts]

    eng = ServeEngine(params, cfg, max_len=80, prefill_chunk=16,
                      prefix_cache_mb=64, mesh=mesh)
    sched = eng.scheduler(slots_per_bucket=2, chunk=3)
    eng.submit(Request(rid=0, tokens=prompts[0], n_steps=6))
    out = dict(eng.drain())  # warm the store with prompt A, then reopen
    eng2 = ServeEngine(params, cfg, max_len=80, prefill_chunk=16,
                       prefix_cache_mb=64, mesh=mesh)
    eng2.prefix_store = eng.prefix_store  # shared store, warm hits
    eng2.scheduler(slots_per_bucket=2, chunk=3)
    eng2.submit(Request(rid=1, tokens=prompts[1], n_steps=6))
    out2 = eng2.drain()
    assert np.array_equal(out[0].tokens, refs[0].tokens[0])
    assert np.array_equal(out2[1].tokens, refs[1].tokens[0])
    assert out2[1].metrics.prefix_hit_tokens >= 16  # warm restore ran
    eng2._check_executable_guard()
    assert eng2.decode_cache_size() <= eng2._scheduler.n_geometries()


@needs_mesh
def test_mesh_generate_matches_single_device():
    """The batch frontend (``generate``) on the mesh: same tokens as
    the single-device engine, chunked and monolithic admission alike."""
    cfg, params = _setup("phi3-mini-3.8b")
    mesh = make_debug_mesh(1, 2)
    rng = np.random.default_rng(9)
    toks = rng.integers(0, cfg.vocab_size, size=24).astype(np.int32)
    for kw in ({}, {"prefill_chunk": 16}):
        ref = ServeEngine(params, cfg, max_len=64, **kw)
        eng = ServeEngine(params, cfg, max_len=64, mesh=mesh, **kw)
        a = ref.generate(toks[None], 6)
        b = eng.generate(toks[None], 6)
        assert np.array_equal(a.tokens, b.tokens), kw
        assert a.routing == b.routing


# ---------------------------------------------------------------------------
# Collective-bytes analytic: O(H·D) per step, never the cache
# ---------------------------------------------------------------------------

@needs_mesh
def test_mesh_decode_collectives_are_activation_sized():
    """Lower the pooled decode scan with mesh-committed inputs and
    count collective bytes in the compiled HLO: the whole n_steps scan
    must move fewer bytes than ONE copy of the pool's KV payload, and
    the per-step collectives must stay under one layer's cache bytes —
    the head-sharded layout attends locally and only combines
    activation-sized partials (row-parallel all-reduce, O(d_model))."""
    from repro.serve.engine import kv_cache_stats
    from repro.serve.slots import SlotPool
    cfg, params = _setup("phi3-mini-3.8b")
    mesh = make_debug_mesh(1, 2)
    eng = ServeEngine(params, cfg, max_len=64, mesh=mesh)
    fa = tuple("fa" if k == "attn" else None for k in cfg.layer_kinds)
    logits_like = jnp.zeros((1, cfg.vocab_size), jnp.float32)
    pool = SlotPool.create(cfg, fa, 2, 64, logits_like, mesh=mesh)
    n_steps = 4
    lowered = eng._decode_many.lower(
        params=eng.params, logits=pool.logits, caches=pool.caches,
        pos=pool.pos, rng=jax.random.key(0), n_steps=n_steps,
        greedy=True, enc_out=None, fa_heads=None, duo_layers=None,
        unroll=eng.decode_unroll)
    cost = HL.loop_aware_costs(lowered.compile().as_text())
    stats = kv_cache_stats(pool.caches)
    assert cost.coll_bytes > 0, "sharded decode lowered no collectives"
    # not O(S·D): the scan's total collective traffic is below one
    # cache copy, and each step moves less than a single layer's KV
    assert cost.coll_bytes < stats.payload_bytes, cost.coll_by_kind
    n_attn = sum(k == "attn" for k in cfg.layer_kinds)
    per_layer_cache = stats.payload_bytes / n_attn
    assert cost.coll_bytes / n_steps < per_layer_cache, cost.coll_by_kind


# ---------------------------------------------------------------------------
# mesh=None: purely additive — bitwise and dispatch-count identical
# ---------------------------------------------------------------------------

def test_mesh_none_is_bitwise_and_dispatch_identical():
    cfg, params = _setup("phi3-mini-3.8b")
    outs, counts = [], []
    for kw in ({}, {"mesh": None}):
        eng = ServeEngine(params, cfg, max_len=64, **kw)
        out = _drain(eng, _mixed_requests(cfg, 4),
                     slots_per_bucket=2, chunk=4)
        outs.append({k: v.tokens for k, v in out.items()})
        counts.append(eng.dispatch_count)
    assert counts[0] == counts[1]
    assert sorted(outs[0]) == sorted(outs[1])
    assert all(np.array_equal(outs[0][k], outs[1][k]) for k in outs[0])


def test_kv_stats_shard_bytes_equal_global_without_mesh():
    """On one device the per-shard figures are the global figures —
    the split only diverges under a committed 'model' axis."""
    from repro.serve import kv_cache
    from repro.serve.engine import kv_cache_stats
    cfg, _ = _setup("phi3-mini-3.8b")
    fa = tuple("fa" if k == "attn" else None for k in cfg.layer_kinds)
    caches = kv_cache.init_decode_caches(cfg, fa, 2, 64)
    stats = kv_cache_stats(caches)
    assert stats.payload_shard_bytes == stats.payload_bytes
    assert stats.overhead_shard_bytes == stats.overhead_bytes


@needs_mesh
def test_mesh_kv_stats_split_shard_vs_global_bytes():
    """Head-sharded k/v leaves divide by the model-axis size per shard;
    replicated bookkeeping does not.  Global figures are untouched, so
    the memory ledger's reconciliation stays exact, and the flight
    recorder's tick records carry the mesh shape."""
    cfg, params = _setup("phi3-mini-3.8b")
    mesh = make_debug_mesh(1, 2)
    eng = ServeEngine(params, cfg, max_len=64, mesh=mesh,
                      memory_ledger=True, telemetry=True)
    _drain(eng, _mixed_requests(cfg, 3, n_steps=4),
           slots_per_bucket=3, chunk=4)
    rep = eng.ledger_report()
    st = rep["kv_cache_stats"]
    # phi3 smoke is all-attention FullKV: every payload leaf is a
    # head-sharded k or v, so per-shard is exactly half of global
    assert st["payload_shard_bytes"] * 2 == st["payload_bytes"]
    assert st["overhead_shard_bytes"] == st["overhead_bytes"]
    assert rep["mesh"] == [1, 2]
    recon = rep["reconciliation"]
    assert recon["payload_delta"] == 0
    assert recon["overhead_delta"] == rep["aux_bytes"]
    rec = eng.flight_recorder.last()
    assert rec is not None and rec.mesh == (1, 2)
    assert rec.as_dict()["mesh"] == [1, 2]
