"""Training loop + data pipeline + checkpointing."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev dep (pyproject [dev]); skip, never break collection
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config, smoke_variant
from repro.core.sparsity import TASK_HOLISTIC, TASK_RETRIEVAL
from repro.data import SyntheticTasks, mixture_iterator
from repro.data.synthetic import KEY, QUERY, SYM0, VALUE
from repro.models import model as MD
from repro.train import (PretrainTrainer, RouterTrainer, checkpoint,
                         cross_entropy)
from repro.train.train_loop import chunked_cross_entropy
from repro.train import optimizer as OPT


# ---------------------------------------------------------------------------
# Data
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.sampled_from([64, 100, 128]))
def test_needle_batch_invariants(B, S):
    gen = SyntheticTasks(vocab=256, seed=0)
    rng = np.random.default_rng(1)
    b = gen.needle_batch(rng, B, S)
    assert b.tokens.shape == (B, S)
    assert (b.loss_mask.sum(1) == 1).all()  # exactly one answer position
    for i in range(B):
        toks = b.tokens[i]
        # the queried key appears in exactly one (KEY, k, v, SEP) record
        key = toks[-1]
        recs = np.where(toks == KEY)[0]
        vals = [toks[p + 2] for p in recs if p + 2 < S
                and toks[p + 1] == key]
        assert vals == [b.labels[i, -1]]
    assert (b.task_type == TASK_RETRIEVAL).all()


def test_multihop_chain():
    gen = SyntheticTasks(vocab=256, seed=0)
    rng = np.random.default_rng(2)
    b = gen.multihop_batch(rng, 4, 96)
    for i in range(4):
        toks = b.tokens[i]
        k0 = toks[-1]
        recs = {}
        for p in np.where(toks == KEY)[0]:
            if p + 2 < 96:
                recs[toks[p + 1]] = toks[p + 2]
        assert recs[recs[k0]] == b.labels[i, -1]


def test_markov_task_type():
    gen = SyntheticTasks(vocab=256, seed=0)
    b = gen.markov_batch(np.random.default_rng(0), 2, 32)
    assert (b.task_type == TASK_HOLISTIC).all()
    assert (b.tokens >= SYM0).all()
    assert b.loss_mask.all()


def test_mixture_iterator_balanced():
    it = mixture_iterator(256, 4, 64, seed=0)
    types = [next(it).task_type[0] for _ in range(60)]
    frac = np.mean([t == TASK_RETRIEVAL for t in types])
    assert 0.2 < frac < 0.8


# ---------------------------------------------------------------------------
# Optimizer / losses
# ---------------------------------------------------------------------------

def test_chunked_ce_matches_dense():
    rng = np.random.default_rng(0)
    B, S, d, V = 2, 20, 8, 32
    h = jnp.asarray(rng.normal(size=(B, S, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, V)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
    mask = jnp.asarray(rng.random((B, S)) > 0.3, jnp.float32)
    dense = cross_entropy(h @ w, labels, mask)
    chunked = chunked_cross_entropy(h, w, labels, mask, chunk=7)
    assert abs(float(dense) - float(chunked)) < 1e-4


def test_adamw_descends_quadratic():
    p = {"x": jnp.asarray([5.0, -3.0])}
    state = OPT.adamw_init(p)
    for _ in range(200):
        g = jax.grad(lambda q: jnp.sum(q["x"] ** 2))(p)
        p, state = OPT.adamw_update(g, state, p, lr=0.1,
                                    weight_decay=0.0)
    assert float(jnp.abs(p["x"]).max()) < 0.1


def test_adamw_ascend_flips_direction():
    p = {"l": jnp.asarray([0.5])}
    state = OPT.adamw_init(p)
    g = {"l": jnp.asarray([1.0])}  # ∂L/∂λ > 0 ⇒ ascent increases λ
    p2, _ = OPT.adamw_update(g, state, p, lr=0.1, ascend=True)
    assert float(p2["l"][0]) > 0.5


def test_partition_combine_roundtrip():
    tree = {"a": jnp.ones(3), "b": {"c": jnp.zeros(2), "d": jnp.ones(1)}}
    mask = {"a": True, "b": {"c": False, "d": True}}
    tr, fz = OPT.partition(tree, mask)
    assert tr["b"]["c"] is None and fz["a"] is None
    merged = OPT.combine(tr, fz)
    assert all((x == y).all() for x, y in
               zip(jax.tree.leaves(merged), jax.tree.leaves(tree)))


# ---------------------------------------------------------------------------
# Trainers
# ---------------------------------------------------------------------------

def test_router_training_moves_msr_toward_target():
    """Soft MSR should approach the per-task budget under the
    Lagrangian (paper Fig. 10c)."""
    cfg = smoke_variant(get_config("phi3-mini-3.8b")).replace(
        vocab_size=64)
    params = MD.init_params(jax.random.key(0), cfg)
    rt = RouterTrainer(cfg, total_steps=60)
    state = rt.init(params)
    it = mixture_iterator(cfg.vocab_size, 8, 64, seed=0)
    state, hist = rt.run(state, it, 60, log_every=59,
                         log_fn=lambda *_: None)
    # sparsity loss should not blow up; λ stays ≥ 0
    assert all(l >= 0 for l in hist[-1]["lambda1"])
    assert np.isfinite(hist[-1]["loss"])


def test_checkpoint_roundtrip_bf16():
    cfg = smoke_variant(get_config("granite-moe-3b-a800m")).replace(
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16)
    params = MD.init_params(jax.random.key(0), cfg)
    with tempfile.TemporaryDirectory() as d:
        f = os.path.join(d, "ck.msgpack")
        checkpoint.save(f, params)
        p2 = checkpoint.load(f, params)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            assert a.dtype == b.dtype
            assert bool((a == b).all())


def test_pretrain_reduces_loss():
    cfg = smoke_variant(get_config("phi3-mini-3.8b")).replace(
        vocab_size=64, flux=get_config("phi3-mini-3.8b").flux.replace(
            enabled=False))
    params = MD.init_params(jax.random.key(0), cfg)
    pt = PretrainTrainer(cfg, total_steps=40, lr=3e-3)
    st = pt.init(params)
    it = mixture_iterator(cfg.vocab_size, 8, 64, seed=0,
                          weights={"markov": 1.0})
    st, hist = pt.run(st, it, 40, log_every=39, log_fn=lambda *_: None)
    assert hist[-1]["loss"] < hist[0]["loss"]
