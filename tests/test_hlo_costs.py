"""Loop-aware HLO cost walker — calibration against hand-counted
programs (the dry-run roofline depends on these semantics)."""
import jax
import jax.numpy as jnp
from jax import lax

from repro.launch.hlo_costs import loop_aware_costs


def _hlo(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


X = jnp.ones((64, 64))
W = jnp.ones((8, 64, 64))
MM = 2 * 64 ** 3  # one 64³ matmul


def test_scan_body_times_trip_count():
    def scanned(x, w):
        return lax.scan(lambda h, wi: (h @ wi, None), x, w)[0]

    def unrolled(x, w):
        h = x
        for i in range(8):
            h = h @ w[i]
        return h

    c_scan = loop_aware_costs(_hlo(scanned, X, W))
    c_unr = loop_aware_costs(_hlo(unrolled, X, W))
    assert c_scan.flops == c_unr.flops == 8 * MM


def test_nested_scans_multiply():
    w2 = jnp.ones((4, 8, 64, 64))

    def nested(x, w2):
        def outer(h, ws):
            return lax.scan(lambda h2, wi: (h2 @ wi, None), h, ws)[0], None
        return lax.scan(outer, x, w2)[0]

    assert loop_aware_costs(_hlo(nested, X, w2)).flops == 32 * MM


def test_cond_takes_max_branch():
    def f(p, x, w):
        return lax.cond(p > 0, lambda: (x @ w[0]) @ w[1],
                        lambda: x @ w[0])

    c = loop_aware_costs(_hlo(f, jnp.int32(1), X, W))
    assert c.flops == 2 * MM  # not 3·MM (sum) — one branch runs


def test_xla_cost_analysis_undercounts_loops():
    """Document the raw behaviour our walker corrects."""
    def scanned(x, w):
        return lax.scan(lambda h, wi: (h @ wi, None), x, w)[0]

    raw = jax.jit(scanned).lower(X, W).compile().cost_analysis()
    if isinstance(raw, (list, tuple)):  # jax 0.4.x returns [dict]
        raw = raw[0]
    # body counted once (±loop bookkeeping ops) instead of ×8
    assert float(raw["flops"]) < 1.01 * MM
