"""Static baselines, entropy ranking, sharding rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core import policies
from repro.distributed import constrain, logical_rules
from repro.models import model as MD


def test_static_patterns():
    cfg = get_config("stablelm-12b")  # 40 routed layers
    for placement in ("deep", "shallow", "interleave"):
        pat = policies.static_pattern(cfg, 0.5, placement)
        assert pat.shape == (40,)
        assert (pat == 0).sum() == 20
    deep = policies.static_pattern(cfg, 0.25, "deep")
    assert deep[:30].all() and not deep[30:].any()


def test_static_pattern_respects_non_routed():
    cfg = get_config("jamba-1.5-large-398b")  # 9 attn of 72
    pat = policies.static_pattern(cfg, 0.5, "deep")
    routed = cfg.routable_layers()
    assert (pat == 0).sum() == round(0.5 * len(routed))
    for i, k in enumerate(cfg.layer_kinds):
        if k != "attn":
            assert pat[i] == 1  # only attn layers are sparsified


def test_matrix_entropy_orders_information():
    rng = np.random.default_rng(0)
    rich = jnp.asarray(rng.normal(size=(2, 32, 16)), jnp.float32)
    rank1 = jnp.asarray(
        rng.normal(size=(2, 32, 1)) @ rng.normal(size=(1, 16)),
        jnp.float32)
    assert float(policies.matrix_entropy(rich)) > float(
        policies.matrix_entropy(rank1))


def test_entropy_pattern_keeps_high_entropy_layers():
    cfg = smoke_variant(get_config("phi3-mini-3.8b"))
    scores = [0.1, 0.9]
    pat = policies.entropy_pattern(cfg, scores, msr=0.5)
    assert pat[1] == 1 and pat[0] == 0


def test_duo_n_fa_kv():
    cfg = get_config("stablelm-12b")
    assert policies.duo_n_fa_kv(cfg, 0.5) == 4
    assert policies.duo_n_fa_kv(cfg, 1.0) == 1  # at least one


# ---------------------------------------------------------------------------
# Sharding
# ---------------------------------------------------------------------------

def test_constrain_noop_without_rules():
    x = jnp.ones((4, 8))
    y = constrain(x, "batch", "heads")
    assert y is x


def test_param_spec_rules():
    from jax.sharding import PartitionSpec as P
    from repro.launch import shardings as SH
    from repro.launch.mesh import make_debug_mesh
    mesh = make_debug_mesh(1, 1)

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    spec = SH.param_spec((12288, 12288), FakeMesh())
    assert spec == P("data", "model")
    spec = SH.param_spec((40, 1536, 512), FakeMesh(), skip_leading=1)
    assert spec == P(None, "data", "model")
    # non-divisible dims stay unsharded
    spec = SH.param_spec((7, 13), FakeMesh())
    assert spec == P(None, None)


def test_constrain_divisibility_fallback():
    """8 kv heads on a 16-way model axis must NOT be sharded."""
    from repro.launch.mesh import make_debug_mesh, mesh_context
    mesh = make_debug_mesh(1, 1)
    rules = {"kv_heads": ("model",), "batch": ("data",)}
    with mesh_context(mesh), logical_rules(rules):
        @jax.jit
        def f(x):
            return constrain(x, "batch", "kv_heads", None, None)
        out = f(jnp.ones((2, 8, 4, 4)))
        assert out.shape == (2, 8, 4, 4)


def test_representative_pattern():
    from repro.launch.workloads import representative_pattern
    cfg = get_config("gemma3-12b")
    pat = representative_pattern(cfg, 0.5)
    assert len(pat) == 48
    routed = [p for p in pat if p is not None]
    assert len(routed) == 8  # 1-in-6 global layers
    assert abs(routed.count("sa") / len(routed) - 0.5) <= 0.13
