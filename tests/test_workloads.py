"""Launch-layer workload builders: lower+compile on a tiny debug mesh
with smoke configs (the real thing is launch/dryrun.py on 512 devices —
this guards the plumbing in the normal test environment)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, smoke_variant
from repro.configs.base import InputShape
from repro.distributed import logical_rules
from repro.launch import workloads as WL
from repro.launch import hlo_analysis as HA
from repro.launch.mesh import make_debug_mesh, mesh_context

SMALL = {
    "train": InputShape("t", 64, 2, "train"),
    "prefill": InputShape("p", 64, 2, "prefill"),
    "decode": InputShape("d", 64, 2, "decode"),
}


def _lower(cfg, shape, **kw):
    mesh = make_debug_mesh(1, 1)
    wl = WL.build_workload(cfg, shape, mesh, **kw)
    with mesh_context(mesh), logical_rules(wl.rules):
        compiled = jax.jit(wl.fn, in_shardings=wl.in_shardings).lower(
            *wl.args).compile()
        hlo = compiled.as_text()
    return compiled, hlo


@pytest.mark.parametrize("arch", ["phi3-mini-3.8b", "gemma3-12b",
                                  "granite-moe-3b-a800m",
                                  "jamba-1.5-large-398b", "mamba2-780m",
                                  "whisper-tiny", "phi-3-vision-4.2b",
                                  "deepseek-v2-236b"])
@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_workload_lowers(arch, kind):
    cfg = smoke_variant(get_config(arch))
    compiled, hlo = _lower(cfg, SMALL[kind])
    terms = HA.roofline_terms(compiled, hlo, 1)
    assert terms["hlo_flops_per_chip"] > 0
    assert terms["t_compute_s"] >= 0


@pytest.mark.parametrize("arch", ["phi3-mini-3.8b",
                                  "jamba-1.5-large-398b",
                                  "deepseek-v2-236b"])
def test_prefill_chunked_workload_lowers(arch):
    cfg = smoke_variant(get_config(arch))
    shape = InputShape("pc", 64, 2, "prefill_chunked")
    compiled, hlo = _lower(cfg, shape, chunk=16)
    terms = HA.roofline_terms(compiled, hlo, 1)
    assert terms["hlo_flops_per_chip"] > 0


def test_decode_variants_lower():
    cfg = smoke_variant(get_config("phi3-mini-3.8b"))
    _lower(cfg, SMALL["decode"], decode_tp=True)
    _lower(cfg, SMALL["decode"], msr=1.0)


def test_train_no_seq_shard_lowers():
    cfg = smoke_variant(get_config("phi3-mini-3.8b"))
    _lower(cfg, SMALL["train"], seq_shard=False)


def test_causal_split_workload():
    cfg = smoke_variant(get_config("phi3-mini-3.8b")).replace(
        causal_split_depth=2)
    _lower(cfg, SMALL["prefill"])
