"""Cost attribution: memory ledger, tick profiler, fidelity probes
(DESIGN.md §Observability, ISSUE 9).

The load-bearing claims:
  1. attribution OFF is free — and attribution ON (profiler + ledger,
     no probes) is *still* bitwise-identical on tokens, dispatch counts
     and the decode-executable census: the profiler adds sync
     boundaries only on sampled ticks, never dispatches, and the
     ledger is pure host arithmetic;
  2. the ledger reconciles against an independent ``kv_cache_stats``
     walk exactly on payload and prefix tiers, and within exactly
     ``aux_bytes`` on overhead — at every tick, under churn;
  3. fidelity probes add exactly the probe forwards (one per sampled
     admission) and only probe-bucket executables, and their coverage
     is exact: a prompt inside the SA sink+local window must measure
     coverage == 1.0, and the padded probe form is bitwise equal to
     the unpadded forward;
  4. the analytic tick-cost join (hlo_costs) splits kernel-hit vs
     declined layers and scales with steps — checked against the
     per-layer cost model it is built from.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core import router as RT
from repro.launch import hlo_costs as HL
from repro.models import model as MD
from repro.serve import Request, ServeEngine
from repro.serve import telemetry as TM
from repro.serve.engine import kv_cache_stats
from repro.serve.scheduler import ContinuousScheduler


def _setup(arch="phi3-mini-3.8b"):
    cfg = smoke_variant(get_config(arch))
    params = MD.init_params(jax.random.key(0), cfg)
    return cfg, params


def _prompt(cfg, n=20, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)


def _drain(cfg, params, n=5, **engine_kw):
    eng = ServeEngine(params, cfg, max_len=64, **engine_kw)
    sched = ContinuousScheduler(eng, slots_per_bucket=2, chunk=2,
                                prefill_chunks_per_tick=4)
    for i in range(n):
        sched.submit(Request(rid=i, tokens=_prompt(cfg, 12 + 5 * i, seed=i),
                             n_steps=6))
    return eng, sched, sched.drain()


# ---------------------------------------------------------------------------
# Off is free; profiler+ledger on is bitwise and census-identical
# ---------------------------------------------------------------------------

def test_attribution_on_bitwise_parity_and_zero_new_executables():
    cfg, params = _setup()
    eng0, _, res0 = _drain(cfg, params)
    eng1, _, res1 = _drain(cfg, params, profile_every=2,
                           memory_ledger=True)
    assert set(res0) == set(res1)
    for rid in res0:
        assert np.array_equal(res0[rid].tokens, res1[rid].tokens), rid
        assert res0[rid].status == res1[rid].status
    assert eng0.dispatch_count == eng1.dispatch_count
    assert eng0.decode_cache_size() == eng1.decode_cache_size()
    assert eng0._decode_keys == eng1._decode_keys
    # the default engine holds no attribution objects at all
    assert eng0.profiler is None and eng0.ledger is None
    assert eng0.fidelity_probe_every == 0


def test_attribution_disabled_reports_raise():
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, max_len=64)
    with pytest.raises(ValueError, match="profiler is disabled"):
        eng.profiler_report()
    with pytest.raises(ValueError, match="ledger is disabled"):
        eng.ledger_report()
    with pytest.raises(ValueError):
        ServeEngine(params, cfg, max_len=64, profile_every=-1)
    with pytest.raises(ValueError):
        ServeEngine(params, cfg, max_len=64, fidelity_probe_every=-1)


# ---------------------------------------------------------------------------
# Memory ledger: exact reconciliation under churn, fragmentation
# ---------------------------------------------------------------------------

def test_ledger_reconciles_exactly_at_every_tick_under_churn():
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, max_len=64, memory_ledger=True)
    # slots_per_bucket=1 with mixed lengths + priorities forces churn:
    # admissions, waiting, retirement all overlap across ticks
    sched = ContinuousScheduler(eng, slots_per_bucket=1, chunk=2,
                                prefill_chunks_per_tick=2)
    for i in range(6):
        sched.submit(Request(rid=i, tokens=_prompt(cfg, 10 + 7 * i, seed=i),
                             n_steps=5, priority=i % 3))
    checked = 0
    while sched.waiting or sched.n_active():
        sched.tick()
        rep = eng.ledger_report()
        recon = rep["reconciliation"]
        assert recon["payload_delta"] == 0, (sched.ticks, recon)
        assert recon["prefix_device_delta"] == 0, recon
        assert recon["prefix_host_delta"] == 0, recon
        # ledger overhead exceeds the cache walk by exactly the pool
        # aux (logits/pos) buffers the walk never sees
        assert recon["overhead_delta"] == rep["aux_bytes"], recon
        checked += 1
        if checked > 500:
            pytest.fail("drain did not converge")
    assert checked > 1  # churn actually spanned multiple ticks
    snap = eng.ledger.last()
    assert snap.device_bytes <= eng.ledger.high_watermark
    # everything idle now: no queued work, so all stranded bytes are
    # fragmentation, and nothing is live
    assert snap.pool_live_bytes == 0
    assert snap.fragmentation_bytes == snap.pool_stranded_bytes > 0
    # params are part of the tracked device figure
    assert snap.params_bytes == eng._params_cost()[1] > 0


def test_ledger_tick_records_and_gauges():
    cfg, params = _setup()
    eng, sched, _ = _drain(cfg, params, memory_ledger=True)
    recs = eng.flight_recorder.dump()
    assert recs, "telemetry (implied by ledger) records ticks"
    assert any(r["ledger_device_bytes"] > 0 for r in recs)
    text = eng.metrics_text()
    samples = TM.parse_prometheus_text(text)
    assert "serve_ledger_device_bytes" in samples
    assert "serve_ledger_device_high_watermark_bytes" in samples
    (_, hwm), = samples["serve_ledger_device_high_watermark_bytes"]
    assert hwm == eng.ledger.high_watermark > 0


def test_pool_ledger_entry_fragmentation_semantics():
    e = TM.PoolLedgerEntry(pool="g0", capacity=4, occupied=1,
                           slot_payload_bytes=100, slot_overhead_bytes=8,
                           aux_bytes=64, queued_match=False)
    assert e.live_bytes == 100
    assert e.stranded_bytes == 300
    assert e.fragmentation_bytes == 300  # nobody queued wants this pool
    assert e.overhead_bytes == 4 * 8 + 64
    assert e.total_bytes == 4 * 108 + 64
    # a queued request routing here makes the empty slots useful again
    e.queued_match = True
    assert e.fragmentation_bytes == 0
    assert e.stranded_bytes == 300  # stranded is occupancy, not demand


def test_queued_geometry_suppresses_fragmentation():
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, max_len=64, memory_ledger=True)
    sched = ContinuousScheduler(eng, slots_per_bucket=1, chunk=2)
    # two identical prompts: same routing → same geometry bucket; with
    # one slot the second request queues behind the first
    sched.submit(Request(rid=0, tokens=_prompt(cfg, 16), n_steps=8))
    sched.submit(Request(rid=1, tokens=_prompt(cfg, 16), n_steps=8))
    for _ in range(50):
        sched.tick()
        if sched.pools and sched.waiting:
            resident = {inf.req.rid for p in sched.pools.values()
                        for inf in p.active.values()}
            waiter = sched.waiting[0]
            if resident and (waiter.job is not None
                             and waiter.job.caches is not None
                             or waiter.cached_key is not None):
                snap = eng.ledger.last()
                # pool is full (occupied == capacity): nothing stranded,
                # and the waiter's known geometry matches the pool
                assert all(p.queued_match or p.stranded_bytes == 0
                           for p in snap.pools)
                assert snap.fragmentation_bytes == 0
                break
    sched.drain()


def test_prefix_store_watermarks_track_peaks():
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, max_len=96, prefill_chunk=16,
                      prefix_cache_mb=0.2, prefix_cache_host_mb=0.2,
                      memory_ledger=True)
    sched = ContinuousScheduler(eng, slots_per_bucket=2, chunk=2)
    shared = _prompt(cfg, 32, seed=99)
    for i in range(4):
        toks = np.concatenate([shared, _prompt(cfg, 8, seed=i)])
        sched.submit(Request(rid=i, tokens=toks.astype(np.int32),
                             n_steps=4))
    sched.drain()
    s = eng.prefix_store.stats()
    assert s.device_high_watermark >= s.device_bytes
    assert s.device_high_watermark > 0
    assert s.host_high_watermark >= s.host_bytes
    # the ledger's prefix tier agrees with the store exactly
    recon = eng.ledger_report()["reconciliation"]
    assert recon["prefix_device_delta"] == 0
    assert recon["prefix_host_delta"] == 0


# ---------------------------------------------------------------------------
# Tick profiler: sampling cadence, phases, expressed-cost join
# ---------------------------------------------------------------------------

def test_profiler_samples_on_cadence_with_expressed_costs():
    cfg, params = _setup()
    eng, sched, _ = _drain(cfg, params, profile_every=2)
    rep = eng.profiler_report()
    assert rep["every"] == 2
    assert rep["sampled_ticks"] == sched.ticks // 2
    phases = {p["phase"]: p for p in rep["phases"]}
    assert "queue" in phases and "decode" in phases
    dec = phases["decode"]
    assert dec["expressed_flops"] > 0
    assert dec["expressed_hbm_bytes"] > 0
    assert dec["host_s"] >= 0 and dec["device_s"] >= 0
    assert 0.0 <= dec["host_frac"] <= 1.0
    # without a decode kernel installed every attention layer declines
    assert "kernel_hit" not in phases
    assert phases["kernel_decline"]["expressed_hbm_bytes"] > 0
    # decline layers' cost is folded into the decode totals
    assert (phases["kernel_decline"]["expressed_flops"]
            <= dec["expressed_flops"])


def test_profiler_validation():
    with pytest.raises(ValueError, match="every"):
        TM.TickProfiler(0)
    p = TM.TickProfiler(3)
    assert [t for t in range(1, 10) if p.should_sample(t)] == [3, 6, 9]


# ---------------------------------------------------------------------------
# hlo_costs tick-cost join
# ---------------------------------------------------------------------------

def test_pooled_decode_tick_cost_matches_per_layer_model():
    lengths = [5, 40, 1]
    specs = [(64, 8, 2, 32, 32, 4), (20, 8, 8, 16, 16, 2)]
    hits = [True, False]
    out = HL.pooled_decode_tick_cost(lengths, specs, n_steps=3,
                                     kernel_hits=hits, block_k=8)
    expect_f = expect_b = 0.0
    for (buf, hq, hkv, dk, dv, db), hit in zip(specs, hits):
        c = HL.pooled_decode_attn_cost(lengths, buf, n_q_heads=hq,
                                       n_kv_heads=hkv, d_k=dk, d_v=dv,
                                       block_k=8, dtype_bytes=db)
        expect_f += (c["kernel_flops"] if hit else c["dense_flops"]) * 3
        expect_b += (c["kernel_hbm_bytes"] if hit
                     else c["dense_hbm_bytes"]) * 3
    assert out["flops"] == expect_f
    assert out["hbm_bytes"] == expect_b
    assert out["kernel_hit"]["layers"] == 3      # 1 hit layer × 3 steps
    assert out["kernel_decline"]["layers"] == 3
    assert (out["kernel_hit"]["flops"] + out["kernel_decline"]["flops"]
            == out["flops"])
    # default = all-dense
    dense = HL.pooled_decode_tick_cost(lengths, specs, block_k=8)
    assert dense["kernel_hit"]["layers"] == 0
    with pytest.raises(ValueError, match="kernel_hits"):
        HL.pooled_decode_tick_cost(lengths, specs, kernel_hits=[True])


def test_decode_linear_cost():
    c = HL.decode_linear_cost(1_000, 4_000, batch=4, n_steps=8)
    assert c["flops"] == 2.0 * 1_000 * 4 * 8
    assert c["hbm_bytes"] == 4_000.0 * 8  # batch shares one param read


# ---------------------------------------------------------------------------
# Fidelity probes
# ---------------------------------------------------------------------------

def test_fidelity_probes_bitwise_tokens_and_bounded_executables():
    cfg, params = _setup()
    eng0, _, res0 = _drain(cfg, params)
    eng1, _, res1 = _drain(cfg, params, fidelity_probe_every=1)
    for rid in res0:
        assert np.array_equal(res0[rid].tokens, res1[rid].tokens), rid
    # probes add exactly one dispatch per sampled admission, nothing on
    # the decode path
    assert (eng1.dispatch_count - eng0.dispatch_count
            == eng1._probe_admissions)
    assert eng1.decode_cache_size() == eng0.decode_cache_size()
    assert eng1._decode_keys == eng0._decode_keys
    # probe executables are bounded by the padded power-of-two buckets
    assert eng1._coverage._cache_size() <= len(eng1._probe_keys)
    # every-1 probing: every finished request carries a fidelity score
    for rid, f in res1.items():
        assert f.metrics.fidelity is not None, rid
        assert 0.0 <= f.metrics.fidelity <= 1.0 + 1e-5
    # sampled cadence: every-3 probes ~1/3 of admissions
    eng3, _, res3 = _drain(cfg, params, fidelity_probe_every=3)
    probed = [f for f in res3.values()
              if f.metrics.fidelity is not None]
    assert 0 < len(probed) < len(res3)


def test_probe_coverage_one_inside_sa_window():
    cfg, params = _setup()
    sa = cfg.flux
    short = sa.sink + sa.local  # whole prompt visible to the SA mask
    eng, sched, res = _drain(cfg, params, n=1, fidelity_probe_every=1)
    assert res[0].metrics.fidelity is not None
    cov = eng._maybe_fidelity_probe(_prompt(cfg, min(short, 48)),
                                    ("sa",) * cfg.num_layers)
    np.testing.assert_allclose(np.asarray(cov), 1.0, atol=1e-6)


def test_padded_probe_matches_unpadded():
    # the probe pads prompts to power-of-two buckets and masks by
    # length; the padded form must agree with the direct forward to
    # reduction-order noise (XLA sums in shape-dependent order, so
    # bitwise equality across shapes is not a meaningful target)
    cfg, params = _setup()
    S = 27  # pads to 32
    toks = _prompt(cfg, S, seed=3)
    direct = MD.attention_mass_coverage(params, cfg,
                                        jnp.asarray(toks)[None])
    padded = np.zeros((1, 32), np.int32)
    padded[0, :S] = toks
    via_pad = MD.attention_mass_coverage(params, cfg,
                                         jnp.asarray(padded),
                                         length=jnp.int32(S))
    np.testing.assert_allclose(np.asarray(direct), np.asarray(via_pad),
                               rtol=1e-5, atol=1e-6)


def test_fidelity_histograms_and_drain_summary():
    cfg, params = _setup()
    eng, sched, res = _drain(cfg, params, fidelity_probe_every=1)
    samples = TM.parse_prometheus_text(eng.metrics_text())
    assert "flux_fidelity_coverage" in samples
    summ = eng._drain_summary(res)
    assert summ["fidelity_probed"] == len(res)
    assert 0.0 <= summ["fidelity_p50"] <= 1.0 + 1e-5
    assert 0.0 <= summ["fidelity_min"] <= 1.0 + 1e-5


# ---------------------------------------------------------------------------
# Margin drift tracker
# ---------------------------------------------------------------------------

def test_margin_drift_tracker_math():
    md = RT.MarginDriftTracker(window=4)
    for m in (0.1, 0.2, 0.3):
        md.observe(0, 0, m)
    assert md.drift(0, 0) == pytest.approx(0.0)  # window == lifetime
    # lifetime mean drags behind a shifted recent window
    for m in (0.9, 0.9, 0.9, 0.9):
        md.observe(0, 0, m)
    lifetime = (0.1 + 0.2 + 0.3 + 4 * 0.9) / 7
    assert md.drift(0, 0) == pytest.approx(0.9 - lifetime)
    assert md.drift(5, 1) == 0.0  # unseen key
    md.observe(1, 2, -0.5)
    assert md.keys() == ((0, 0), (1, 2))
    rep = md.report()
    assert rep["0:0"]["count"] == 7
    assert rep["1:2"]["drift"] == pytest.approx(0.0)
    with pytest.raises(ValueError, match="window"):
        RT.MarginDriftTracker(0)


def test_margin_drift_exported_from_drain():
    cfg, params = _setup()
    eng, _, _ = _drain(cfg, params, telemetry=True)
    rep = eng.attribution_report()
    assert rep["margin_drift"], "routed layers must have observed margins"
    for st in rep["margin_drift"].values():
        assert st["count"] > 0
    samples = TM.parse_prometheus_text(eng.metrics_text())
    assert "flux_router_margin_drift" in samples
