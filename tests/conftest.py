import os
import sys

# Tests and benches must see exactly ONE device (the dry-run pins 512
# inside launch/dryrun.py only — never globally).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")
