"""Per-architecture smoke tests — deliverable (f).

Each assigned arch instantiates a REDUCED same-family variant
(≤ pattern-length layers, d_model ≤ 256, ≤ 4 experts) and runs one
forward/train step on CPU, asserting output shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, smoke_variant
from repro.models import model as MD
from repro.train import RouterTrainer

B, S = 2, 48


def _inputs(cfg):
    kw = {}
    if cfg.family == "vlm":
        kw["prefix_embeddings"] = jax.random.normal(
            jax.random.key(5), (B, cfg.num_prefix_tokens, cfg.d_model),
            cfg.dtype)
    if cfg.family == "audio":
        kw["encoder_frames"] = jax.random.normal(
            jax.random.key(6), (B, cfg.encoder_ctx, cfg.d_model), cfg.dtype)
    return kw


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = smoke_variant(get_config(arch))
    assert cfg.num_layers <= max(2, len(cfg.layer_pattern))
    assert cfg.d_model <= 256
    assert cfg.num_experts <= 4
    params = MD.init_params(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0,
                                cfg.vocab_size)
    out = MD.forward_train(params, cfg, tokens, rng=jax.random.key(2),
                           tau=1.0, remat=False, **_inputs(cfg))
    assert out.logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(out.logits.astype(jnp.float32)).all())
    n_routed = len(cfg.routable_layers()) if cfg.flux.enabled else 0
    if n_routed:
        assert out.r_soft.shape == (B, n_routed)
        assert bool(((out.r_soft >= 0) & (out.r_soft <= 1)).all())
    else:
        assert out.r_soft is None


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step(arch):
    cfg = smoke_variant(get_config(arch)).replace(vocab_size=128)
    params = MD.init_params(jax.random.key(0), cfg)
    trainer = RouterTrainer(cfg, total_steps=10)
    state = trainer.init(params)
    tokens = np.asarray(
        jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size))
    labels = np.roll(tokens, -1, axis=1)
    mask = np.ones((B, S), np.float32)
    task = np.zeros((B,), np.int32)
    kw = _inputs(cfg)
    if kw:  # step_impl path with modality extras
        new_state, metrics = jax.jit(
            lambda st, t, l, m, tt, r: trainer.step_impl(
                st, t, l, m, tt, r, **kw))(
            state, tokens, labels, mask, task, jax.random.key(3))
    else:
        new_state, metrics = trainer.step(state, tokens, labels, mask,
                                          task, jax.random.key(3))
    assert bool(jnp.isfinite(metrics["loss"]))
    # backbone strictly frozen
    same = jax.tree.map(
        lambda a, b: bool((a == b).all()) if a is not None else True,
        state["frozen"], new_state["frozen"],
        is_leaf=lambda x: x is None)
    assert all(jax.tree.leaves(same))


@pytest.mark.parametrize("arch", ["mamba2-780m"])
def test_ssm_has_no_router(arch):
    """Flux is inapplicable to attention-free archs (DESIGN.md
    §Arch-applicability) — asserted, not skipped."""
    cfg = get_config(arch)
    assert not cfg.flux.enabled
    assert cfg.routable_layers() == ()
