"""Layer Router + sparsity objective, incl. hypothesis properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev dep (pyproject [dev]); skip, never break collection
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import FluxConfig
from repro.core import router as R
from repro.core import sparsity as SP

FLUX = FluxConfig(pool_size=8, router_hidden=16)


def _params(in_dim=32):
    return R.router_init(jax.random.key(0), in_dim, FLUX)


def test_router_shapes():
    p = _params()
    x = jnp.asarray(np.random.default_rng(0).normal(size=(3, 40, 32)),
                    jnp.float32)
    logits = R.router_logits(p, x, FLUX.pool_size)
    assert logits.shape == (3, 2)
    r = R.soft_route(p, x, FLUX, 1.0, jax.random.key(1))
    assert r.shape == (3,)
    assert bool(((r > 0) & (r < 1)).all())
    hard, pfa = R.hard_route(p, x, FLUX)
    assert set(np.asarray(hard).tolist()) <= {0, 1}


def test_pooling_length_invariance():
    """Paper Fig. 9: router cost/feature depends only on the boundary
    tokens — identical prefix+suffix ⇒ identical decision at any S."""
    p = _params()
    rng = np.random.default_rng(1)
    pre = rng.normal(size=(1, 8, 32))
    suf = rng.normal(size=(1, 8, 32))
    for mid_len in (0, 16, 256):
        mid = rng.normal(size=(1, mid_len, 32))
        x = jnp.asarray(np.concatenate([pre, mid, suf], 1), jnp.float32)
        out = R.router_logits(p, x, FLUX.pool_size)
        if mid_len == 0:
            base = out
        else:
            assert float(jnp.abs(out - base).max()) < 1e-5


def test_gumbel_softmax_converges_to_argmax():
    """As τ→0 the soft weight approaches the hard decision (the paper's
    train→inference discretization) — for *confident* logits; a random
    init gives ~zero margin, so scale the input to separate them."""
    p = _params()
    x = jnp.asarray(np.random.default_rng(2).normal(size=(4, 20, 32)),
                    jnp.float32) * 50.0
    logits = R.router_logits(p, x, FLUX.pool_size)
    margin = np.abs(np.asarray(logits[:, 0] - logits[:, 1]))
    assert margin.max() > 0.5  # confident examples exist at this scale
    confident = margin > 0.5
    hard, _ = R.hard_route(p, x, FLUX)
    agree, n = 0.0, 50
    for i in range(n):
        r = R.soft_route(p, x, FLUX, 0.01, jax.random.key(i))
        match = (np.asarray(r > 0.5).astype(int) == np.asarray(hard))
        agree += match[confident].mean()
    assert agree / n > 0.9


def test_anneal_tau_monotone():
    flux = FluxConfig(tau_start=5.0, tau_end=0.1)
    taus = [float(R.anneal_tau(flux, s, 100)) for s in range(0, 101, 10)]
    assert taus[0] == pytest.approx(5.0)
    assert taus[-1] == pytest.approx(0.1)
    assert all(a >= b for a, b in zip(taus, taus[1:]))


# ---------------------------------------------------------------------------
# Sparsity objective
# ---------------------------------------------------------------------------

def test_msr():
    r = jnp.asarray([[1, 0, 0, 1], [0, 0, 0, 0]], jnp.float32)
    np.testing.assert_allclose(np.asarray(SP.msr(r)), [0.5, 1.0])


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 6), st.integers(1, 8), st.integers(0, 1))
def test_sparsity_loss_properties(B, L, task):
    """Loss is zero-gradient-free only at the budget; deviation is
    penalized in the direction of the sign of λ terms."""
    flux = FluxConfig()
    lag = {"lambda1": jnp.asarray([0.5, 0.5]),
           "lambda2": jnp.asarray([1.0, 1.0])}
    t = float(SP.target_table(flux)[task])
    task_type = jnp.full((B,), task, jnp.int32)
    # exactly at budget → L_diff = 0 → loss 0
    r_at = jnp.full((B, L), 1.0 - t, jnp.float32)
    loss_at, diag = SP.sparsity_loss(r_at, task_type, lag, flux)
    assert abs(float(loss_at)) < 1e-5
    # above-budget sparsity costs more via the quadratic term
    r_over = jnp.clip(r_at - 0.3, 0.0, 1.0)
    loss_over, _ = SP.sparsity_loss(r_over, task_type, lag, flux)
    r_under = jnp.clip(r_at + 0.3, 0.0, 1.0)
    loss_under, _ = SP.sparsity_loss(r_under, task_type, lag, flux)
    assert float(loss_over) >= float(loss_at) - 1e-6 or \
        float(loss_under) <= float(loss_at) + 1e-6


def test_lagrange_projection():
    lag = {"lambda1": jnp.asarray([-0.5, 0.3]),
           "lambda2": jnp.asarray([0.1, -2.0])}
    p = SP.project_lagrange(lag)
    assert bool((p["lambda1"] >= 0).all())
    assert bool((p["lambda2"] >= 0).all())


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(0.0, 1.0), min_size=2, max_size=16))
def test_msr_bounds(rs):
    """Ω_MSR ∈ [0, 1] for any routing vector (hypothesis)."""
    r = jnp.asarray(rs, jnp.float32)[None]
    m = float(SP.msr(r)[0])
    assert -1e-6 <= m <= 1 + 1e-6
