"""End-to-end behaviour tests for the paper's system.

The full loop: pretrain a tiny backbone on the synthetic mixture →
train the Layer Router (frozen backbone, Lagrangian budget) → serve
with hard routing and sparse decode → verify the paper's qualitative
claims at miniature scale:

  1. retrieval accuracy collapses under all-SA when the needle falls
     outside the window (Fig. 1a);
  2. flux routing preserves retrieval accuracy at lower cost than
     all-FA decode memory;
  3. the router differentiates task types (Fig. 4 / Fig. 10c).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.data import SyntheticTasks, mixture_iterator, retrieval_accuracy
from repro.models import model as MD
from repro.serve import ServeEngine
from repro.serve.engine import kv_cache_bytes, repack_caches
from repro.train import PretrainTrainer, RouterTrainer


SEQ = 96


@pytest.fixture(scope="module")
def trained():
    cfg = smoke_variant(get_config("phi3-mini-3.8b")).replace(
        vocab_size=64,
        flux=smoke_variant(get_config("phi3-mini-3.8b")).flux.replace(
            sink=4, local=16))
    params = MD.init_params(jax.random.key(0), cfg)
    it = mixture_iterator(cfg.vocab_size, 16, SEQ, seed=0,
                          weights={"markov": 0.5, "needle": 0.5})
    # 1000 steps: enough for induction to form under this jax/backend's
    # numerics (400 left needle accuracy at chance-adjacent 0.25)
    pt = PretrainTrainer(cfg, total_steps=1000, lr=3e-3)
    st = pt.init(params)
    st, _ = pt.run(st, it, 1000, log_every=10000, log_fn=lambda *_: None)
    params = st["params"]
    rt = RouterTrainer(cfg, total_steps=80)
    rstate = rt.init(params)
    rstate, _ = rt.run(rstate, it, 80, log_every=1000,
                       log_fn=lambda *_: None)
    return cfg, rt.params(rstate)


def _eval(cfg, params, task, pattern=None, n=24, needle_pos=None):
    gen = SyntheticTasks(cfg.vocab_size, seed=0)
    rng = np.random.default_rng(42)
    kw = {"needle_pos": needle_pos} if (task == "needle"
                                        and needle_pos is not None) else {}
    b = gen.batch(rng, task, n, SEQ, **kw)
    if pattern is None:
        out = MD.prefill(params, cfg, jnp.asarray(b.tokens),
                         routing_ctx="fa_only", want_cache=False)
    else:
        out = MD.prefill(params, cfg, jnp.asarray(b.tokens),
                         routing_ctx="fixed",
                         fixed_pattern=jnp.asarray(pattern),
                         want_cache=False)
    pred = np.asarray(jnp.argmax(out.logits, -1))
    return float((pred == b.labels[:, -1]).mean())


def test_backbone_learns_retrieval(trained):
    cfg, params = trained
    acc = _eval(cfg, params, "needle")
    # kv-pool chance ≈ 0.035; induction formed ⇒ well above it
    assert acc > 0.25, f"pretrained backbone should retrieve, acc={acc}"


def test_sparsity_collapses_early_needle(trained):
    """Fig. 1a: needles far outside the sink+local window are
    unreachable under all-SA, while all-FA retrieves them."""
    cfg, params = trained
    ones = np.ones(cfg.num_layers, np.int64)
    acc_fa = _eval(cfg, params, "needle", ones, needle_pos=0.3)
    acc_sa = _eval(cfg, params, "needle", ones * 0, needle_pos=0.3)
    assert acc_fa > acc_sa + 0.15, (acc_fa, acc_sa)


def test_holistic_robust_to_sparsity(trained):
    """Markov LM depends on local context only — all-SA ≈ all-FA."""
    cfg, params = trained
    gen = SyntheticTasks(cfg.vocab_size, seed=0)
    b = gen.markov_batch(np.random.default_rng(9), 16, SEQ)
    toks = jnp.asarray(b.tokens)
    fa = MD.prefill(params, cfg, toks, routing_ctx="fixed",
                    fixed_pattern=jnp.ones(cfg.num_layers, jnp.int32),
                    want_cache=False)
    sa = MD.prefill(params, cfg, toks, routing_ctx="fixed",
                    fixed_pattern=jnp.zeros(cfg.num_layers, jnp.int32),
                    want_cache=False)
    pred_fa = np.asarray(jnp.argmax(fa.logits, -1))
    pred_sa = np.asarray(jnp.argmax(sa.logits, -1))
    agree = float((pred_fa == pred_sa).mean())
    assert agree > 0.6, agree


def test_engine_sparse_decode_saves_memory(trained):
    cfg, params = trained
    gen = SyntheticTasks(cfg.vocab_size, seed=0)
    b = gen.batch(np.random.default_rng(3), "markov", 2, SEQ)
    eng = ServeEngine(params, cfg, max_len=SEQ + 8,
                      routing_override=tuple(
                          "sa" for _ in cfg.layer_kinds))
    dense = ServeEngine(params, cfg, max_len=SEQ + 8,
                        sparse_decode=False)
    g_sa = eng.generate(b.tokens, 2)
    g_fa = dense.generate(b.tokens, 2)
    assert g_sa.kv_bytes < g_fa.kv_bytes


def test_router_runs_once_and_is_cached(trained):
    """§3.3: the routing decision from prefill is reused across decode
    steps (the pattern is part of the generation result)."""
    cfg, params = trained
    gen = SyntheticTasks(cfg.vocab_size, seed=0)
    b = gen.batch(np.random.default_rng(5), "needle", 1, SEQ)
    eng = ServeEngine(params, cfg, max_len=SEQ + 8)
    out = eng.generate(b.tokens, 3)
    assert len(out.routing) == cfg.num_layers
    assert all(p in ("fa", "sa", None) for p in out.routing)
