"""Device-resident decode loop (DESIGN.md §Serving).

Covers the serving acceptance invariants: the scanned generator is
bitwise-identical to a per-step sample→decode python loop; a request
costs O(1) compiled dispatches, not O(n_steps); the decode jit cache is
keyed by cache geometry, so routing patterns sharing a geometry share
one executable; and the Pallas flash-decode kernel adapter matches the
dense decode dot.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.kernels.decode_attention import make_kernel_decode_attn
from repro.models import model as MD
from repro.serve import ServeEngine

B, S, N = 2, 24, 5


def _setup(arch, **replace):
    cfg = smoke_variant(get_config(arch))
    if replace:
        cfg = cfg.replace(**replace)
    params = MD.init_params(jax.random.key(0), cfg)
    toks = np.asarray(jax.random.randint(jax.random.key(1), (B, S), 0,
                                         cfg.vocab_size))
    return cfg, params, toks


def _loop_generate(eng, cfg, params, toks, n_steps, *, greedy=True,
                   rng=None):
    """The seed's per-step host loop: sample on device, sync the token,
    dispatch one decode jit per step.  Reference for bitwise equality
    with the fused scan — admission goes through the engine's own
    pipeline so only the decode strategy differs."""
    job = eng.prefill_chunked(jnp.asarray(toks))
    pattern, caches, logits = job.pattern, job.caches, job.logits
    out, pos = [], S
    for _ in range(n_steps):
        if greedy or rng is None:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            rng, k = jax.random.split(rng)
            nxt = jax.random.categorical(k, logits).astype(jnp.int32)
        out.append(np.asarray(nxt))
        logits, caches = MD.decode_step(params, cfg, nxt[:, None], caches,
                                        pattern, jnp.int32(pos))
        pos += 1
    return np.stack(out, axis=1)


@pytest.mark.parametrize("arch", ["phi3-mini-3.8b", "jamba-1.5-large-398b",
                                  "deepseek-v2-236b"])
def test_scan_generate_bitwise_matches_step_loop(arch):
    cfg, params, toks = _setup(arch)
    eng = ServeEngine(params, cfg, max_len=S + N + 3)
    gen = eng.generate(toks, N)
    ref = _loop_generate(eng, cfg, params, toks, N)
    assert np.array_equal(gen.tokens, ref)


def test_scan_generate_sampling_matches_step_loop():
    cfg, params, toks = _setup("phi3-mini-3.8b")
    eng = ServeEngine(params, cfg, max_len=S + N + 3)
    rng = jax.random.key(7)
    gen = eng.generate(toks, N, greedy=False, rng=rng)
    ref = _loop_generate(eng, cfg, params, toks, N, greedy=False, rng=rng)
    assert np.array_equal(gen.tokens, ref)


def test_generate_is_constant_dispatch():
    """O(1) compiled calls per request regardless of n_steps."""
    cfg, params, toks = _setup("phi3-mini-3.8b")
    eng = ServeEngine(params, cfg, max_len=S + 34)
    before = eng.dispatch_count
    gen_short = eng.generate(toks, 2)
    mid = eng.dispatch_count
    gen_long = eng.generate(toks, 32)
    after = eng.dispatch_count
    # routing chunk + seed + per-chunk streams + one decode scan; the
    # count depends on the prompt's chunk plan, never on n_steps
    from repro.serve import chunk_plan
    expect = 2 + (len(chunk_plan(S, eng.prefill_chunk)) - 1) + 1
    assert gen_short.dispatches == gen_long.dispatches == expect
    assert mid - before == after - mid == expect


def test_same_geometry_patterns_share_one_executable():
    """Different routing patterns with identical cache geometry (all
    full KV, differing only in the traced head-split) must hit one
    compiled decode executable."""
    cfg, params, toks = _setup("phi3-mini-3.8b")
    eng = ServeEngine(params, cfg, max_len=S + N + 3)
    duo1 = tuple(("duo", 1) if k == "attn" else None
                 for k in cfg.layer_kinds)
    duo2 = tuple(("duo", 2) if k == "attn" else None
                 for k in cfg.layer_kinds)
    t1 = eng.generate(toks, N, routing_override=duo1)
    size1 = eng.decode_cache_size()
    t2 = eng.generate(toks, N, routing_override=duo2)
    size2 = eng.decode_cache_size()
    assert size1 == size2 == 1
    assert t1.routing != t2.routing  # genuinely different patterns


def test_executable_count_stays_per_geometry():
    """The jit cache grows only when the geometry (or n_steps bucket)
    changes — never per routing pattern."""
    cfg, params, toks = _setup("phi3-mini-3.8b")
    eng = ServeEngine(params, cfg, max_len=S + N + 3)
    fa = tuple("fa" if k == "attn" else None for k in cfg.layer_kinds)
    sa = tuple("sa" if k == "attn" else None for k in cfg.layer_kinds)
    eng.generate(toks, N, routing_override=fa)
    assert eng.decode_cache_size() == 1
    eng.generate(toks, N, routing_override=sa)   # new geometry → +1
    assert eng.decode_cache_size() == 2
    eng.generate(toks, N, routing_override=sa)   # repeat → reuse
    assert eng.decode_cache_size() == 2
    eng._check_executable_guard()


def test_kernel_decode_adapter_matches_dense():
    rng = np.random.default_rng(0)
    B_, Hq, Hkv, L, D = 2, 4, 2, 64, 16
    q = jnp.asarray(rng.normal(size=(B_, Hq, 1, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B_, Hkv, L, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B_, Hkv, L, D)), jnp.float32)
    valid = jnp.arange(L) <= 40
    fn = make_kernel_decode_attn(block_k=16, min_len=16, interpret=True)
    out = fn(q, k, v, valid)
    ref = MD._dot_decode(q, k, v, valid)
    assert float(jnp.abs(out - ref).max()) < 2e-5
    # declines per-head masks and short caches
    assert fn(q, k, v, jnp.stack([valid, valid])) is None
    assert make_kernel_decode_attn(min_len=128)(
        q, k, v, valid) is None


def test_engine_with_kernel_decode_backend():
    cfg, params, toks = _setup("phi3-mini-3.8b")
    eng_ref = ServeEngine(params, cfg, max_len=S + N + 3)
    eng_krn = ServeEngine(params, cfg, max_len=S + N + 3,
                          decode_attn=make_kernel_decode_attn(
                              block_k=16, min_len=16, interpret=True))
    ref = eng_ref.generate(toks, N)
    out = eng_krn.generate(toks, N)
    assert out.tokens.shape == ref.tokens.shape
    assert np.array_equal(out.tokens, ref.tokens)
