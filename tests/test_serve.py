"""Serving-path correctness: decode == teacher-forced prefill for every
cache type, engine routing, repack, sparse-decode memory claim."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.models import model as MD
from repro.serve import (ServeEngine, Request, kv_cache, repack_caches,
                         serve_batch)
from repro.serve.engine import kv_cache_bytes

ARCHS_DECODE = ["phi3-mini-3.8b", "stablelm-12b", "deepseek-v2-236b",
                "gemma3-12b", "jamba-1.5-large-398b", "mamba2-780m",
                "granite-moe-3b-a800m"]
B, S, N = 2, 48, 4


def _setup(arch):
    cfg = smoke_variant(get_config(arch))
    params = MD.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (B, S + N), 0,
                              cfg.vocab_size)
    return cfg, params, toks


def _run_decode(cfg, params, toks, pattern, fixed):
    pf = MD.prefill(params, cfg, toks[:, :S], routing_ctx="fixed",
                    fixed_pattern=fixed)
    caches = repack_caches(cfg, pf.caches, pattern, S, S + N)
    logits = pf.logits
    for i in range(N):
        logits, caches = MD.decode_step(
            params, cfg, toks[:, S + i:S + i + 1], caches, pattern,
            jnp.int32(S + i))
    return logits


@pytest.mark.parametrize("arch", ARCHS_DECODE)
@pytest.mark.parametrize("sa", [False, True])
def test_decode_matches_teacher_forced_prefill(arch, sa):
    cfg, params, toks = _setup(arch)
    fixed = jnp.full((cfg.num_layers,), 0 if sa else 1, jnp.int32)
    mode = "sa" if sa else "fa"
    pattern = tuple(mode if k == "attn" else None
                    for k in cfg.layer_kinds)
    logits = _run_decode(cfg, params, toks, pattern, fixed)
    ref = MD.prefill(params, cfg, toks, routing_ctx="fixed",
                     fixed_pattern=fixed).logits
    scale = float(jnp.abs(ref).max()) + 1e-6
    assert float(jnp.abs(logits - ref).max()) / scale < 1e-4


def test_duo_headsplit_decode_consistency():
    cfg, params, toks = _setup("stablelm-12b")
    n_fa = 1
    pf = MD.prefill(params, cfg, toks[:, :S], routing_ctx="head_split",
                    head_split_n=n_fa)
    pattern = tuple(("duo", n_fa) if k == "attn" else None
                    for k in cfg.layer_kinds)
    full = tuple("fa" if k == "attn" else None for k in cfg.layer_kinds)
    caches = repack_caches(cfg, pf.caches, full, S, S + N)
    logits = pf.logits
    for i in range(N):
        logits, caches = MD.decode_step(
            params, cfg, toks[:, S + i:S + i + 1], caches, pattern,
            jnp.int32(S + i))
    ref = MD.prefill(params, cfg, toks, routing_ctx="head_split",
                     head_split_n=n_fa).logits
    scale = float(jnp.abs(ref).max()) + 1e-6
    assert float(jnp.abs(logits - ref).max()) / scale < 1e-4


def test_sparse_decode_cache_smaller():
    """The paper's KV saving: all-SA decode caches ≪ all-FA caches."""
    cfg, params, toks = _setup("phi3-mini-3.8b")
    long_max = 4 * (cfg.flux.sink + cfg.flux.local)
    pf = MD.prefill(params, cfg, toks[:, :S])
    fa = repack_caches(cfg, pf.caches,
                       tuple("fa" for _ in cfg.layer_kinds), S, long_max)
    sa = repack_caches(cfg, pf.caches,
                       tuple("sa" for _ in cfg.layer_kinds), S, long_max)
    assert kv_cache_bytes(sa) < 0.5 * kv_cache_bytes(fa)


def test_engine_generate_and_bucketing():
    cfg, params, _ = _setup("granite-moe-3b-a800m")
    eng = ServeEngine(params, cfg, max_len=S + 16)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, tokens=rng.integers(
        0, cfg.vocab_size, size=S).astype(np.int32), n_steps=3)
        for i in range(3)]
    out = serve_batch(eng, reqs)
    assert sorted(out) == [0, 1, 2]
    assert all(v.shape == (3,) for v in out.values())


def test_routing_override():
    cfg, params, toks = _setup("phi3-mini-3.8b")
    override = tuple("sa" if k == "attn" else None
                     for k in cfg.layer_kinds)
    eng = ServeEngine(params, cfg, max_len=S + 8,
                      routing_override=override)
    gen = eng.generate(np.asarray(toks[:, :S]), 2)
    assert gen.msr == 1.0
    assert gen.routing == override


# ---------------------------------------------------------------------------
# repack_caches edge cases
# ---------------------------------------------------------------------------

def test_repack_prompt_shorter_than_sink():
    """seq_len <= sink: the ring holds exactly the prompt, decode still
    matches teacher-forced prefill."""
    cfg, params, toks = _setup("phi3-mini-3.8b")
    short = cfg.flux.sink - 2  # < sink (smoke sink = 8)
    fixed = jnp.zeros((cfg.num_layers,), jnp.int32)  # all SA
    pattern = tuple("sa" if k == "attn" else None for k in cfg.layer_kinds)
    pf = MD.prefill(params, cfg, toks[:, :short], routing_ctx="fixed",
                    fixed_pattern=fixed)
    caches = repack_caches(cfg, pf.caches, pattern, short, short + N)
    logits = pf.logits
    for i in range(N):
        logits, caches = MD.decode_step(
            params, cfg, toks[:, short + i:short + i + 1], caches, pattern,
            jnp.int32(short + i))
    ref = MD.prefill(params, cfg, toks[:, :short + N],
                     routing_ctx="fixed", fixed_pattern=fixed).logits
    scale = float(jnp.abs(ref).max()) + 1e-6
    assert float(jnp.abs(logits - ref).max()) / scale < 1e-4


def test_repack_max_len_truncates_ring():
    """sink < max_len < sink+local: the ring shrinks to max_len slots —
    the sink plus the most recent (max_len - sink) positions."""
    cfg, params, toks = _setup("phi3-mini-3.8b")
    flux = cfg.flux
    max_len = flux.sink + 8  # < sink + local (smoke: 8 + 32)
    pattern = tuple("sa" if k == "attn" else None for k in cfg.layer_kinds)
    pf = MD.prefill(params, cfg, toks[:, :S])
    caches = repack_caches(cfg, pf.caches, pattern, S, max_len)
    ring = [c for c in caches if isinstance(c, kv_cache.RingKV)][0]
    assert ring.k.shape[2] == max_len
    assert ring.positions.shape == (B, max_len)  # per-slot bookkeeping
    kept = sorted(int(p) for p in np.asarray(ring.positions[0]) if p >= 0)
    expect = sorted(set(range(flux.sink)) | set(range(S - 8, S)))
    assert kept == expect


def test_repack_prompt_longer_than_max_len_rejected():
    """seq_len > max_len must raise a loud ValueError naming both values
    — not a negative pad surfacing as a cryptic XLA shape error."""
    cfg, params, toks = _setup("phi3-mini-3.8b")
    pattern = tuple("fa" if k == "attn" else None for k in cfg.layer_kinds)
    pf = MD.prefill(params, cfg, toks[:, :S])
    with pytest.raises(ValueError) as ei:
        repack_caches(cfg, pf.caches, pattern, S, S - 4)
    assert f"seq_len={S}" in str(ei.value)
    assert f"max_len={S - 4}" in str(ei.value)


def test_init_layer_cache_rejects_nonpositive_max_len():
    cfg, _, _ = _setup("phi3-mini-3.8b")
    with pytest.raises(ValueError, match="max_len=0"):
        kv_cache.init_layer_cache(cfg, "attn", "fa", 1, 0)


def test_kv_cache_stats_splits_payload_from_overhead():
    """positions/length bookkeeping must not pollute the paper's
    KV-reduction numbers: kv_cache_bytes counts payload only."""
    from repro.serve.engine import kv_cache_stats
    cfg, params, toks = _setup("phi3-mini-3.8b")
    pattern = tuple("sa" if k == "attn" else None for k in cfg.layer_kinds)
    pf = MD.prefill(params, cfg, toks[:, :S])
    caches = repack_caches(cfg, pf.caches, pattern, S, S + N)
    stats = kv_cache_stats(caches)
    ring = [c for c in caches if isinstance(c, kv_cache.RingKV)]
    expect_overhead = sum(
        c.positions.size * c.positions.dtype.itemsize
        + c.length.size * c.length.dtype.itemsize for c in caches
        if hasattr(c, "length"))
    assert ring and stats.overhead_bytes == expect_overhead
    assert stats.payload_bytes + stats.overhead_bytes == stats.total_bytes
    assert kv_cache_bytes(caches) == stats.payload_bytes
    # raw leaf-sum counts strictly more than the payload
    raw = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(caches))
    assert raw == stats.total_bytes > stats.payload_bytes


def test_repack_max_len_below_sink_rejected():
    """max_len <= sink leaves no local ring slots — a loud error, not a
    degenerate modulo-zero cache."""
    cfg, params, toks = _setup("phi3-mini-3.8b")
    pattern = tuple("sa" if k == "attn" else None for k in cfg.layer_kinds)
    pf = MD.prefill(params, cfg, toks[:, :S])
    with pytest.raises(ValueError, match="local slots"):
        repack_caches(cfg, pf.caches, pattern, S, cfg.flux.sink)


def test_ring_latent_roundtrip_vs_dense_reference():
    """MLA: the RingLatentKV decode must equal an absorbed decode over
    the dense LatentKV cache restricted to the ring's positions."""
    cfg, params, toks = _setup("deepseek-v2-236b")
    flux = cfg.flux
    pattern_sa = tuple("sa" if k == "attn" else None
                       for k in cfg.layer_kinds)
    pattern_fa = tuple("fa" if k == "attn" else None
                       for k in cfg.layer_kinds)
    pf = MD.prefill(params, cfg, toks[:, :S])
    ring_caches = repack_caches(cfg, pf.caches, pattern_sa, S, S + N)
    full_caches = repack_caches(cfg, pf.caches, pattern_fa, S, S + N)
    # ring slots carry exactly the sink + local-window latents of the
    # dense cache (round-trip of the repack gather)
    layer = cfg.layer_kinds.index("attn")
    ring, full = ring_caches[layer], full_caches[layer]
    assert isinstance(ring, kv_cache.RingLatentKV)
    pos_np = np.asarray(ring.positions[0])  # rows identical after repack
    for slot, p in enumerate(pos_np):
        if p < 0:
            continue
        np.testing.assert_array_equal(np.asarray(ring.ckv[:, slot]),
                                      np.asarray(full.ckv[:, p]))
        np.testing.assert_array_equal(np.asarray(ring.kr[:, :, slot]),
                                      np.asarray(full.kr[:, :, p]))
    # one decode step: ring output == dense output masked to the ring's
    # positions (plus the newly inserted token)
    tok = toks[:, S:S + 1]
    logits_ring, _ = MD.decode_step(params, cfg, tok, ring_caches,
                                    pattern_sa, jnp.int32(S))
    # inserting position S evicts whatever previously held its ring slot
    local = ring.ckv.shape[1] - flux.sink
    evicted = int(pos_np[flux.sink + (S - flux.sink) % local])
    visible = (set(int(p) for p in pos_np if p >= 0) - {evicted}) | {S}
    fixed = jnp.ones((cfg.num_layers,), jnp.int32)

    import repro.models.attention as A

    def masked_dense(bp, cfg_, x, pos, cache):
        positions = pos[None]
        ckv, kr = A.mla_latent(bp["attn"], cfg_, x, positions)
        cache = kv_cache.latent_insert(cache, ckv, kr, pos)
        valid = jnp.asarray([int(i) in visible
                             for i in range(cache.ckv.shape[1])])
        y = A.mla_absorbed_decode(bp["attn"], cfg_, x, positions,
                                  cache.ckv, cache.kr,
                                  valid[None].repeat(x.shape[0], 0))
        return y, cache

    # dense reference: run decode_core but intercept the attn layers
    h = MD.embed_tokens(params, cfg, jnp.asarray(tok))
    caches_ref = list(full_caches)
    from repro.models import moe as MOE
    from repro.models.layers import ffn_apply, rms_norm
    for i, kind in enumerate(cfg.layer_kinds):
        bp = MD.layer_params(params, cfg, i)
        x = rms_norm(bp["norm1"], h, cfg.norm_eps)
        y, caches_ref[i] = masked_dense(bp, cfg, x, jnp.int32(S),
                                        caches_ref[i])
        h = h + y
        if MD.has_ffn(cfg, i):
            x2 = rms_norm(bp["norm2"], h, cfg.norm_eps)
            if "moe" in bp:
                y2, _ = MOE.moe_apply(bp["moe"], cfg, x2)
            else:
                y2 = ffn_apply(bp["ffn"], x2)
            h = h + y2
    logits_ref = MD.logits_from_hidden(params, cfg, h[:, -1])
    scale = float(jnp.abs(logits_ref).max()) + 1e-6
    assert float(jnp.abs(logits_ring - logits_ref).max()) / scale < 1e-4
