"""Serving-path correctness: decode == teacher-forced prefill for every
cache type, engine routing, repack, sparse-decode memory claim."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.models import model as MD
from repro.serve import ServeEngine, Request, repack_caches, serve_batch
from repro.serve.engine import kv_cache_bytes

ARCHS_DECODE = ["phi3-mini-3.8b", "stablelm-12b", "deepseek-v2-236b",
                "gemma3-12b", "jamba-1.5-large-398b", "mamba2-780m",
                "granite-moe-3b-a800m"]
B, S, N = 2, 48, 4


def _setup(arch):
    cfg = smoke_variant(get_config(arch))
    params = MD.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (B, S + N), 0,
                              cfg.vocab_size)
    return cfg, params, toks


def _run_decode(cfg, params, toks, pattern, fixed):
    pf = MD.prefill(params, cfg, toks[:, :S], routing_ctx="fixed",
                    fixed_pattern=fixed)
    caches = repack_caches(cfg, pf.caches, pattern, S, S + N)
    logits = pf.logits
    for i in range(N):
        logits, caches = MD.decode_step(
            params, cfg, toks[:, S + i:S + i + 1], caches, pattern,
            jnp.int32(S + i))
    return logits


@pytest.mark.parametrize("arch", ARCHS_DECODE)
@pytest.mark.parametrize("sa", [False, True])
def test_decode_matches_teacher_forced_prefill(arch, sa):
    cfg, params, toks = _setup(arch)
    fixed = jnp.full((cfg.num_layers,), 0 if sa else 1, jnp.int32)
    mode = "sa" if sa else "fa"
    pattern = tuple(mode if k == "attn" else None
                    for k in cfg.layer_kinds)
    logits = _run_decode(cfg, params, toks, pattern, fixed)
    ref = MD.prefill(params, cfg, toks, routing_ctx="fixed",
                     fixed_pattern=fixed).logits
    scale = float(jnp.abs(ref).max()) + 1e-6
    assert float(jnp.abs(logits - ref).max()) / scale < 1e-4


def test_duo_headsplit_decode_consistency():
    cfg, params, toks = _setup("stablelm-12b")
    n_fa = 1
    pf = MD.prefill(params, cfg, toks[:, :S], routing_ctx="head_split",
                    head_split_n=n_fa)
    pattern = tuple(("duo", n_fa) if k == "attn" else None
                    for k in cfg.layer_kinds)
    full = tuple("fa" if k == "attn" else None for k in cfg.layer_kinds)
    caches = repack_caches(cfg, pf.caches, full, S, S + N)
    logits = pf.logits
    for i in range(N):
        logits, caches = MD.decode_step(
            params, cfg, toks[:, S + i:S + i + 1], caches, pattern,
            jnp.int32(S + i))
    ref = MD.prefill(params, cfg, toks, routing_ctx="head_split",
                     head_split_n=n_fa).logits
    scale = float(jnp.abs(ref).max()) + 1e-6
    assert float(jnp.abs(logits - ref).max()) / scale < 1e-4


def test_sparse_decode_cache_smaller():
    """The paper's KV saving: all-SA decode caches ≪ all-FA caches."""
    cfg, params, toks = _setup("phi3-mini-3.8b")
    long_max = 4 * (cfg.flux.sink + cfg.flux.local)
    pf = MD.prefill(params, cfg, toks[:, :S])
    fa = repack_caches(cfg, pf.caches,
                       tuple("fa" for _ in cfg.layer_kinds), S, long_max)
    sa = repack_caches(cfg, pf.caches,
                       tuple("sa" for _ in cfg.layer_kinds), S, long_max)
    assert kv_cache_bytes(sa) < 0.5 * kv_cache_bytes(fa)


def test_engine_generate_and_bucketing():
    cfg, params, _ = _setup("granite-moe-3b-a800m")
    eng = ServeEngine(params, cfg, max_len=S + 16)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, tokens=rng.integers(
        0, cfg.vocab_size, size=S).astype(np.int32), n_steps=3)
        for i in range(3)]
    out = serve_batch(eng, reqs)
    assert sorted(out) == [0, 1, 2]
    assert all(v.shape == (3,) for v in out.values())


def test_routing_override():
    cfg, params, toks = _setup("phi3-mini-3.8b")
    override = tuple("sa" if k == "attn" else None
                     for k in cfg.layer_kinds)
    eng = ServeEngine(params, cfg, max_len=S + 8,
                      routing_override=override)
    gen = eng.generate(np.asarray(toks[:, :S]), 2)
    assert gen.msr == 1.0
    assert gen.routing == override
