"""Pallas kernels vs pure-jnp oracles (interpret mode, shape/dtype
sweeps) — deliverable (c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


def mk(B, Hq, Hkv, Sq, Skv, D, dtype=jnp.float32):
    q = jnp.asarray(RNG.normal(size=(B, Hq, Sq, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, Skv, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, Skv, D)), dtype)
    return q, k, v


def fl(x):
    return x.reshape(-1, *x.shape[2:])


def tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,S,D,bq,bk", [
    (1, 2, 1, 128, 32, 32, 32),
    (2, 4, 2, 100, 16, 32, 32),   # unaligned seq
    (1, 2, 2, 256, 64, 64, 128),  # bk > bq
    (1, 8, 2, 64, 8, 16, 16),     # G = 4
])
def test_flash_attention(B, Hq, Hkv, S, D, bq, bk, dtype):
    q, k, v = mk(B, Hq, Hkv, S, S, D, dtype)
    out = ops.flash_attention(q, k, v, block_q=bq, block_k=bk,
                              interpret=True)
    r = ref.flash_attention_ref(fl(q), fl(k), fl(v)).reshape(q.shape)
    assert float(jnp.abs(out.astype(jnp.float32)
                         - r.astype(jnp.float32)).max()) < tol(dtype)


def test_flash_attention_bidirectional():
    q, k, v = mk(1, 2, 2, 96, 96, 32)
    out = ops.flash_attention(q, k, v, causal=False, block_q=32,
                              block_k=32, interpret=True)
    r = ref.flash_attention_ref(fl(q), fl(k), fl(v),
                                causal=False).reshape(q.shape)
    assert float(jnp.abs(out - r).max()) < 2e-5


@pytest.mark.parametrize("S,sink,local,bq,bk", [
    (256, 32, 64, 32, 32),
    (200, 16, 48, 32, 32),   # unaligned seq
    (128, 0, 32, 32, 32),    # pure window
    (256, 32, 32, 64, 32),   # window smaller than q block
])
def test_streaming_attention(S, sink, local, bq, bk):
    q, k, v = mk(1, 2, 1, S, S, 32)
    out = ops.streaming_attention(q, k, v, sink=sink, local=local,
                                  block_q=bq, block_k=bk, interpret=True)
    r = ref.streaming_attention_ref(fl(q), fl(k), fl(v), sink=sink,
                                    local=local).reshape(q.shape)
    assert float(jnp.abs(out - r).max()) < 2e-5


@pytest.mark.parametrize("L,cur,ring", [(96, 63, False), (96, 39, True),
                                        (130, 100, False)])
def test_decode_attention(L, cur, ring):
    B, Hq, Hkv, D = 2, 4, 2, 32
    q, k, v = mk(B, Hq, Hkv, 1, L, D)
    if ring:
        perm = np.concatenate([np.arange(cur + 1),
                               -np.ones(L - cur - 1)])
        pos = jnp.asarray(RNG.permutation(perm), jnp.int32)
    else:
        pos = jnp.arange(L, dtype=jnp.int32)
    out = ops.decode_attention(q, k, v, pos, jnp.int32(cur), block_k=32,
                               interpret=True)
    r = ref.decode_attention_ref(fl(q), fl(k), fl(v), pos,
                                 cur).reshape(q.shape)
    assert float(jnp.abs(out - r).max()) < 2e-5


def test_block_sparse_attention():
    B, Hq, Hkv, S, D, blk = 1, 2, 1, 256, 32, 32
    q, k, v = mk(B, Hq, Hkv, S, S, D)
    nqb, K = S // blk, 3
    sel = np.full((B, Hq, nqb, K), -1, np.int32)
    for h in range(Hq):
        for i in range(nqb):
            cand = RNG.choice(i + 1, size=min(K, i + 1), replace=False)
            sel[0, h, i, :len(cand)] = cand
            if i not in cand:
                sel[0, h, i, 0] = i
    sel = jnp.asarray(sel)
    out = ops.block_sparse_attention(q, k, v, sel, block=blk,
                                     interpret=True)
    r = ref.block_sparse_attention_ref(
        fl(q), fl(k), fl(v), sel.reshape(-1, nqb, K),
        block=blk).reshape(q.shape)
    assert float(jnp.abs(out - r).max()) < 2e-5


def test_block_sparse_duplicate_selection_deduped():
    """Repeated indices in the selection must not double-count."""
    B, Hq, Hkv, S, D, blk = 1, 1, 1, 64, 16, 32
    q, k, v = mk(B, Hq, Hkv, S, S, D)
    sel = jnp.asarray([[[0, 0, 0], [0, 1, 1]]], jnp.int32)[None]
    out = ops.block_sparse_attention(q, k, v, sel[0][None],
                                     block=blk, interpret=True)
    clean = jnp.asarray([[[0, -1, -1], [0, 1, -1]]], jnp.int32)
    r = ref.block_sparse_attention_ref(fl(q), fl(k), fl(v), clean,
                                       block=blk).reshape(q.shape)
    assert float(jnp.abs(out - r).max()) < 2e-5


def test_kernel_matches_modes_engine():
    """Kernels and the jnp mode engine agree (same semantics, two
    implementations)."""
    from repro.core import modes as M
    q, k, v = mk(1, 4, 2, 128, 128, 32)
    a = ops.flash_attention(q, k, v, block_q=32, block_k=32,
                            interpret=True)
    b = M.attention(q, k, v, M.FULL, block_q=32)
    assert float(jnp.abs(a - b).max()) < 2e-5
    a = ops.streaming_attention(q, k, v, sink=32, local=32, block_q=32,
                                block_k=32, interpret=True)
    b = M.attention(q, k, v, M.AttnMode("streaming", sink=32, local=32),
                    block_q=32)
    assert float(jnp.abs(a - b).max()) < 2e-5
