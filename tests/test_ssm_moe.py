"""Mamba2 SSD + MoE correctness, incl. hypothesis shape sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev dep (pyproject [dev]); skip, never break collection
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import ModelConfig
from repro.models import moe as MOE
from repro.models import ssm

RNG = np.random.default_rng(3)


def naive_ssd(x, dt, A, Bm, Cm):
    B_, S_, H_, P_ = x.shape
    N = Bm.shape[-1]
    h = np.zeros((B_, H_, P_, N))
    ys = []
    for s in range(S_):
        dA = np.exp(np.asarray(dt[:, s]) * np.asarray(A))
        h = h * dA[:, :, None, None] + np.einsum(
            "bh,bhp,bn->bhpn", np.asarray(dt[:, s]), np.asarray(x[:, s]),
            np.asarray(Bm[:, s]))
        ys.append(np.einsum("bhpn,bn->bhp", h, np.asarray(Cm[:, s])))
    return np.stack(ys, 1), h


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.integers(3, 40), st.integers(1, 4),
       st.sampled_from([4, 8]), st.sampled_from([2, 4]),
       st.sampled_from([4, 8, 16]))
def test_ssd_chunked_matches_recurrence(B, S, H, P, N, chunk):
    x = jnp.asarray(RNG.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 1.0, size=(B, S, H)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    y, hf = ssm.ssd_chunked(x, dt, A, Bm, Cm, chunk)
    yr, hr = naive_ssd(x, dt, A, Bm, Cm)
    assert np.abs(np.asarray(y) - yr).max() < 1e-4
    assert np.abs(np.asarray(hf) - hr).max() < 1e-4


def _ssm_cfg():
    return ModelConfig(
        name="t", family="ssm", num_layers=1, d_model=32, num_heads=0,
        num_kv_heads=0, head_dim=0, d_ff=0, vocab_size=64,
        layer_pattern=("mamba",), ssm_state_dim=8, ssm_head_dim=16,
        ssm_expand=2, ssm_chunk=8, dtype=jnp.float32,
        param_dtype=jnp.float32)


def test_mamba_block_decode_matches_full():
    cfg = _ssm_cfg()
    params = ssm.mamba_init(jax.random.key(0), cfg)
    x = jnp.asarray(RNG.normal(size=(2, 12, 32)), jnp.float32)
    y_full, _ = ssm.mamba_apply(params, cfg, x)
    y_pre, (h, tail) = ssm.mamba_apply(params, cfg, x[:, :8])
    ys = [y_pre]
    for i in range(8, 12):
        y1, h, tail = ssm.mamba_decode_step(params, cfg, x[:, i:i + 1],
                                            h, tail)
        ys.append(y1)
    err = float(jnp.abs(y_full - jnp.concatenate(ys, 1)).max())
    assert err < 1e-4


def test_mamba_chunked_prefill_state_carry():
    cfg = _ssm_cfg()
    params = ssm.mamba_init(jax.random.key(0), cfg)
    x = jnp.asarray(RNG.normal(size=(1, 15, 32)), jnp.float32)
    y_full, _ = ssm.mamba_apply(params, cfg, x)
    y_a, stt = ssm.mamba_apply(params, cfg, x[:, :6])
    y_b, _ = ssm.mamba_apply(params, cfg, x[:, 6:], state=stt)
    err = float(jnp.abs(y_full - jnp.concatenate([y_a, y_b], 1)).max())
    assert err < 1e-4


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def _moe_cfg(E=4, k=2, dropless=True):
    return ModelConfig(
        name="t", family="moe", num_layers=1, d_model=16, num_heads=2,
        num_kv_heads=2, head_dim=8, d_ff=32, vocab_size=64,
        moe_layers="all", num_experts=E, top_k=k, moe_d_ff=32,
        moe_capacity_factor=float(E) if dropless else 1.0,
        num_shared_experts=1, dtype=jnp.float32, param_dtype=jnp.float32)


def test_moe_dropless_matches_dense_expert_sum():
    """Dropless scatter-dispatch == direct per-token expert evaluation."""
    cfg = _moe_cfg()
    params = MOE.moe_init(jax.random.key(0), cfg)
    x = jnp.asarray(RNG.normal(size=(2, 6, 16)), jnp.float32)
    y, aux = MOE.moe_apply(params, cfg, x)

    xf = x.reshape(-1, 16)
    logits = xf @ params["gate_w"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    ew = params["experts"]

    def expert(e, t):
        h = jax.nn.silu(xf[t] @ ew["gate"][e]) * (xf[t] @ ew["up"][e])
        return h @ ew["down"][e]

    y_ref = np.zeros_like(np.asarray(xf))
    for t in range(xf.shape[0]):
        for j in range(cfg.top_k):
            y_ref[t] += float(top_p[t, j]) * np.asarray(
                expert(int(top_i[t, j]), t))
    from repro.models.layers import ffn_apply
    y_ref += np.asarray(ffn_apply(params["shared"], xf))
    err = np.abs(np.asarray(y).reshape(-1, 16) - y_ref).max()
    assert err < 1e-4
    assert float(aux["drop_fraction"]) == 0.0


def test_moe_capacity_drops_bounded():
    cfg = _moe_cfg(dropless=False)
    params = MOE.moe_init(jax.random.key(0), cfg)
    x = jnp.asarray(RNG.normal(size=(4, 16, 16)), jnp.float32)
    y, aux = MOE.moe_apply(params, cfg, x)
    assert 0.0 <= float(aux["drop_fraction"]) < 1.0
    assert bool(jnp.isfinite(y).all())
    assert float(aux["balance_loss"]) >= 1.0 - 1e-3  # ≥1 by Cauchy-Schwarz


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(1, 8))
def test_moe_load_conservation(B, S):
    """Σ_e load_e == T·k (every assignment lands on exactly one expert)."""
    cfg = _moe_cfg()
    params = MOE.moe_init(jax.random.key(0), cfg)
    x = jnp.asarray(RNG.normal(size=(B, S, 16)), jnp.float32)
    _, aux = MOE.moe_apply(params, cfg, x)
    assert int(aux["load"].sum()) == B * S * cfg.top_k
