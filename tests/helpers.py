"""Shared test utilities."""
import jax
import jax.numpy as jnp
import numpy as np


def naive_attention(q, k, v, mask_fn, q_offset=0, scale=None):
    """Dense masked softmax oracle. q (B,Hq,Sq,D); k/v (B,Hkv,Skv,D);
    mask_fn(q_pos col, k_pos row) → bool."""
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = D ** -0.5 if scale is None else scale
    q5 = q.reshape(B, Hkv, G, Sq, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", q5.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qp = q_offset + jnp.arange(Sq)
    kp = jnp.arange(Skv)
    s = jnp.where(mask_fn(qp[:, None], kp[None, :]), s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, Sq, v.shape[-1]).astype(q.dtype)


def rand_qkv(rng, B, Hq, Hkv, Sq, Skv, D, dtype=jnp.float32):
    q = jnp.asarray(rng.normal(size=(B, Hq, Sq, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Hkv, Skv, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Hkv, Skv, D)), dtype)
    return q, k, v
