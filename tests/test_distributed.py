"""Distributed decode (shard_map LSE combine) — exactness vs the local
path on a 1-device mesh (semantics are mesh-size independent: the
combine is an exact softmax decomposition)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.decode import (lse_combine_decode,
                                      make_distributed_dot_decode)
from repro.launch.mesh import make_debug_mesh
from repro.models import model as MD


def test_lse_combine_matches_local():
    rng = np.random.default_rng(0)
    B, Hq, Hkv, S, D = 2, 4, 2, 64, 16
    q = jnp.asarray(rng.normal(size=(B, Hq, 1, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    valid = jnp.arange(S) <= 40
    mesh = make_debug_mesh(1, 1)
    # the mesh is passed explicitly (shard_map mesh=...) — no ambient
    # jax.set_mesh needed, which also keeps jax 0.4.x compatibility
    out = lse_combine_decode(q, k, v, valid, mesh, ("data",))
    ref = MD._dot_decode(q, k, v, valid)
    assert float(jnp.abs(out - ref).max()) < 2e-5


def test_adapter_declines_small_cache():
    mesh = make_debug_mesh(1, 1)
    fn = make_distributed_dot_decode(mesh, ("data",), min_seq=128)
    q = jnp.zeros((1, 2, 1, 8))
    k = v = jnp.zeros((1, 2, 64, 8))
    assert fn(q, k, v, jnp.ones(64, bool)) is None


def test_override_context():
    rng = np.random.default_rng(1)
    B, Hq, Hkv, S, D = 1, 2, 2, 32, 8
    q = jnp.asarray(rng.normal(size=(B, Hq, 1, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    valid = jnp.ones(S, bool)
    marker = {}

    def fake(q, k, v, valid):
        marker["hit"] = True
        return None  # decline → falls back to local

    with MD.use_decode_attn(fake):
        out = MD._dot_decode(q, k, v, valid)
    assert marker.get("hit")
    ref = MD._dot_decode(q, k, v, valid)
    assert float(jnp.abs(out - ref).max()) == 0.0
