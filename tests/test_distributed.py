"""Distributed decode (shard_map LSE combine) — exactness vs the local
path on a 1-device mesh (semantics are mesh-size independent: the
combine is an exact softmax decomposition)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

from repro.distributed.decode import (lse_combine_decode,
                                      make_distributed_dot_decode)
from repro.launch.mesh import make_debug_mesh, mesh_context
from repro.models import model as MD


def test_lse_combine_matches_local():
    rng = np.random.default_rng(0)
    B, Hq, Hkv, S, D = 2, 4, 2, 64, 16
    q = jnp.asarray(rng.normal(size=(B, Hq, 1, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    valid = jnp.arange(S) <= 40
    mesh = make_debug_mesh(1, 1)
    # the mesh is passed explicitly (shard_map mesh=...) — no ambient
    # jax.set_mesh needed, which also keeps jax 0.4.x compatibility
    out = lse_combine_decode(q, k, v, valid, mesh, ("data",))
    ref = MD._dot_decode(q, k, v, valid)
    assert float(jnp.abs(out - ref).max()) < 2e-5


def test_adapter_declines_small_cache():
    mesh = make_debug_mesh(1, 1)
    fn = make_distributed_dot_decode(mesh, ("data",), min_seq=128)
    q = jnp.zeros((1, 2, 1, 8))
    k = v = jnp.zeros((1, 2, 64, 8))
    assert fn(q, k, v, jnp.ones(64, bool)) is None


def test_override_context():
    rng = np.random.default_rng(1)
    B, Hq, Hkv, S, D = 1, 2, 2, 32, 8
    q = jnp.asarray(rng.normal(size=(B, Hq, 1, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    valid = jnp.ones(S, bool)
    marker = {}

    def fake(q, k, v, valid):
        marker["hit"] = True
        return None  # decline → falls back to local

    with MD.use_decode_attn(fake):
        out = MD._dot_decode(q, k, v, valid)
    assert marker.get("hit")
    ref = MD._dot_decode(q, k, v, valid)
    assert float(jnp.abs(out - ref).max()) == 0.0


# ---------------------------------------------------------------------------
# jax-compat shims (launch/mesh.py, distributed/decode.py)
# ---------------------------------------------------------------------------

def test_make_debug_mesh_raises_when_devices_short():
    """Short device counts must fail loudly at mesh construction, not
    as an opaque jax.make_mesh shape error — the message names the fix
    (the XLA_FLAGS host-device override)."""
    n = len(jax.devices()) + 1
    with pytest.raises(RuntimeError, match="host_platform_device_count"):
        make_debug_mesh(1, n)


def test_mesh_context_is_usable_on_any_jax_version():
    """jax.set_mesh where it exists, the legacy ``with mesh:`` context
    elsewhere — either way the returned object must be a working
    context manager."""
    mesh = make_debug_mesh(1, 1)
    with mesh_context(mesh):
        out = jnp.arange(4.0) + 1
    assert float(out.sum()) == 10.0


def test_shard_map_wrapper_accepts_both_check_kwargs():
    """The check_vma→check_rep rename shim: both values of the flag
    must build a callable wrapper on the installed jax version."""
    from repro.distributed.decode import shard_map
    mesh = make_debug_mesh(1, 1)
    x = jnp.arange(4.0)
    for flag in (False, True):
        f = shard_map(lambda a: a * 2, mesh=mesh, in_specs=(P(),),
                      out_specs=P(), check_vma=flag)
        assert np.array_equal(np.asarray(f(x)), np.arange(4.0) * 2)


@pytest.mark.skipif(len(jax.devices()) < 4,
                    reason="needs 4 devices (XLA_FLAGS="
                           "--xla_force_host_platform_device_count=8)")
def test_flat_axis_index_over_multi_axis_mesh():
    """Row-major flattening over ("data", "model") on a (2, 2) mesh:
    shard (d, m) gets flat index d·model_size + m, matching the device
    order of a P(("data", "model")) output sharding."""
    from repro.distributed.decode import _flat_axis_index, shard_map
    mesh = make_debug_mesh(2, 2)
    out = shard_map(
        lambda: _flat_axis_index(("data", "model")).reshape(1),
        mesh=mesh, in_specs=(), out_specs=P(("data", "model")),
        check_vma=False)()
    assert np.array_equal(np.asarray(out), np.arange(4))


@pytest.mark.skipif(len(jax.devices()) < 4,
                    reason="needs 4 devices (XLA_FLAGS="
                           "--xla_force_host_platform_device_count=8)")
def test_lse_combine_exact_over_multi_axis_kv_shards():
    """The LSE combine is an exact softmax decomposition regardless of
    how many mesh axes split the sequence: (2, 2) over both axes must
    match the local reference to float32 tolerance."""
    rng = np.random.default_rng(2)
    B, Hq, Hkv, S, D = 1, 4, 2, 64, 16
    q = jnp.asarray(rng.normal(size=(B, Hq, 1, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    valid = jnp.arange(S) <= 50
    mesh = make_debug_mesh(2, 2)
    out = lse_combine_decode(q, k, v, valid, mesh, ("data", "model"))
    ref = MD._dot_decode(q, k, v, valid)
    assert float(jnp.abs(out - ref).max()) < 2e-5


# ---------------------------------------------------------------------------
# Distributed adapter trace protocol (same vocabulary as the Pallas
# kernel adapter — engine counters replay these verbatim)
# ---------------------------------------------------------------------------

def test_distributed_adapter_trace_protocol():
    mesh = make_debug_mesh(1, 1)
    fn = make_distributed_dot_decode(mesh, ("data",), min_seq=128)
    assert fn.supports_pooled is False and fn.supports_scale is True
    assert fn.min_len == 128
    q = jnp.zeros((1, 2, 1, 8))
    k = v = jnp.zeros((1, 2, 64, 8))
    # decline: cache below min_seq
    assert fn(q, k, v, jnp.ones(64, bool)) is None
    # decline: pooled per-slot mask (rank 2)
    assert fn(q, k, v, jnp.ones((1, 64), bool)) is None
    assert fn.drain_log() == [("decline", "min_len"),
                              ("decline", "mask_rank")]
    assert fn.trace_log == []  # drain clears in place
    # hit: long-enough cache with a shared mask
    k2 = v2 = jnp.zeros((1, 2, 128, 8))
    assert fn(q, k2, v2, jnp.ones(128, bool)) is not None
    assert fn.drain_log() == [("hit", "lse_combine")]


def test_distributed_adapter_decline_reasons_are_engine_vocabulary():
    """Every decline reason the adapter can emit must be pre-registered
    by the engine's counter set — a new reason label would otherwise
    silently never export."""
    from repro.serve.engine import DECODE_KERNEL_DECLINE_REASONS
    mesh = make_debug_mesh(1, 1)
    fn = make_distributed_dot_decode(mesh, ("data",), min_seq=128)
    q = jnp.zeros((1, 2, 1, 8))
    k = v = jnp.zeros((1, 2, 64, 8))
    fn(q, k, v, jnp.ones(64, bool))
    fn(q, k, v, jnp.ones((1, 64), bool))
    for event, reason in fn.drain_log():
        assert event == "decline"
        assert reason in DECODE_KERNEL_DECLINE_REASONS
