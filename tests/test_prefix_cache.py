"""Shared-prefix radix cache (DESIGN.md §Prefix cache).

Load-bearing guarantees of chunk-boundary snapshot reuse:
  1. snapshot exactness: a hit-path admission — including a full
     store→evict-to-host→restore round trip — produces *bitwise-equal*
     greedy continuations vs the cold chunked path, for every cache
     kind (FullKV / RingKV / LatentKV / RingLatentKV / Mamba incl.
     conv tail) across phi3 / jamba / deepseek;
  2. covered tokens issue NO prefill chunks (the O(unique-suffix)
     admission claim);
  3. store invariants: refcounts never go negative, eviction respects
     in-use pins, byte budgets hold under admit/retire churn, and the
     snapshot copy/restore jit stays O(#geometries);
  4. misconfigurations fail loudly at config time (budget below one
     snapshot, store without the chunked prefill) and snapshot
     publication from a repack-fallback admission raises.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core.router import prefix_routing_reusable
from repro.models import model as MD
from repro.serve import (PrefixStore, Request, ServeEngine, Snapshot,
                         kv_cache_stats)
from repro.serve import prefix_cache as PXC

ARCHS = ["phi3-mini-3.8b", "jamba-1.5-large-398b", "deepseek-v2-236b"]
CH, N = 16, 6


def _setup(arch):
    cfg = smoke_variant(get_config(arch))
    params = MD.init_params(jax.random.key(0), cfg)
    return cfg, params


def _mixed_pattern(cfg):
    flip, out = True, []
    for k in cfg.layer_kinds:
        out.append(("fa" if flip else "sa") if k == "attn" else None)
        flip = not flip if k == "attn" else flip
    return tuple(out)


def _prompts(cfg, prefix_len=32, tails=(16, 13)):
    """Prompts sharing a ``prefix_len``-token prefix, distinct tails."""
    rng = np.random.default_rng(0)
    prefix = rng.integers(0, cfg.vocab_size, size=prefix_len
                          ).astype(np.int32)
    return [np.concatenate([
        prefix, rng.integers(0, cfg.vocab_size, size=t).astype(np.int32)
    ])[None] for t in tails]


# ---------------------------------------------------------------------------
# Snapshot exactness: store → evict-to-host → restore, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_hit_path_bitwise_through_host_roundtrip(arch):
    """Warm the store with one prompt, demote every snapshot to the
    host tier, then serve a second prompt sharing the prefix: greedy
    continuations must be bitwise-equal to the cold chunked path and
    the covered tokens must issue no prefill chunks."""
    cfg, params = _setup(arch)
    pA, pB = _prompts(cfg)
    cold = ServeEngine(params, cfg, max_len=64, prefill_chunk=CH)
    refA, refB = cold.generate(pA, N), cold.generate(pB, N)

    eng = ServeEngine(params, cfg, max_len=64, prefill_chunk=CH,
                      prefix_cache_mb=64, prefix_cache_host_mb=64)
    warm = eng.generate(pA, N)
    assert warm.prefix_hit_tokens == 0
    assert np.array_equal(warm.tokens, refA.tokens)
    # boundaries 16/32/48 published (48 = the whole of pA)
    assert eng.prefix_store.stats().snapshots == 3

    eng.prefix_store.offload_all()
    s = eng.prefix_store.stats()
    assert s.device_bytes == 0 and s.host_bytes > 0

    # full-cover hit: identical prompt, zero chunks streamed
    job = eng.prefill_chunked(jnp.asarray(pA))
    assert job.done and job.chunks_streamed == 0
    assert job.prefix_hit_tokens == pA.shape[1]
    hotA = eng.generate(pA, N)
    assert hotA.prefix_hit_tokens == pA.shape[1]
    assert np.array_equal(hotA.tokens, refA.tokens)

    # partial hit: shared 32-token prefix restored, only the unique
    # tail streams (and the ragged tail is never published)
    hotB = eng.generate(pB, N)
    assert hotB.prefix_hit_tokens == 32
    assert hotB.routing == refB.routing
    assert np.array_equal(hotB.tokens, refB.tokens)
    eng._check_executable_guard()


@pytest.mark.parametrize("arch", ARCHS)
def test_hit_path_bitwise_override_geometry(arch):
    """Fixed mixed fa/sa pattern (ring + full caches in one admission):
    override-keyed snapshots restore bitwise too."""
    cfg, params = _setup(arch)
    ov = _mixed_pattern(cfg)
    pA, pB = _prompts(cfg)
    ref = ServeEngine(params, cfg, max_len=64, prefill_chunk=CH,
                      routing_override=ov).generate(pB, N)
    eng = ServeEngine(params, cfg, max_len=64, prefill_chunk=CH,
                      routing_override=ov, prefix_cache_mb=64,
                      prefix_cache_host_mb=64)
    eng.generate(pA, N)
    eng.prefix_store.offload_all()
    hot = eng.generate(pB, N)
    assert hot.prefix_hit_tokens == 32
    assert np.array_equal(hot.tokens, ref.tokens)
    eng._check_executable_guard()


def test_hit_requires_matching_routing_key():
    """Snapshots published under one override are never offered to
    requests running another (or the live router)."""
    cfg, params = _setup("phi3-mini-3.8b")
    pA, _ = _prompts(cfg)
    eng = ServeEngine(params, cfg, max_len=64, prefill_chunk=CH,
                      prefix_cache_mb=64)
    eng.generate(pA, N)  # router-keyed snapshots
    ov = _mixed_pattern(cfg)
    gen = eng.generate(pA, N, routing_override=ov)
    assert gen.prefix_hit_tokens == 0  # override key ≠ router key
    gen2 = eng.generate(pA, N)
    assert gen2.prefix_hit_tokens == pA.shape[1]


def test_prefix_reuse_opt_out():
    cfg, params = _setup("phi3-mini-3.8b")
    pA, _ = _prompts(cfg)
    eng = ServeEngine(params, cfg, max_len=64, prefill_chunk=CH,
                      prefix_cache_mb=64)
    out = eng.generate(pA, N, prefix_reuse=False)
    assert out.prefix_hit_tokens == 0
    assert eng.prefix_store.stats().inserts == 0  # no publication either
    ref = ServeEngine(params, cfg, max_len=64,
                      prefill_chunk=CH).generate(pA, N)
    assert np.array_equal(out.tokens, ref.tokens)


def test_short_prompt_routing_not_reusable():
    """Router-driven prompts shorter than the pool window must neither
    publish nor hit: their routing decision is length-dependent."""
    cfg, params = _setup("phi3-mini-3.8b")
    flux = cfg.flux
    assert not prefix_routing_reusable(flux, flux.pool_size - 1,
                                       flux.pool_size - 1)
    assert prefix_routing_reusable(flux, flux.pool_size, flux.pool_size)
    assert not prefix_routing_reusable(flux, flux.pool_size,
                                       flux.pool_size,
                                       pooling="prefix_suffix")
    assert prefix_routing_reusable(flux, 1, 1, routable=False)
    # engine-level: chunk == 4 < pool_size == 8 → a 4-token-boundary
    # snapshot would predate the pool window; nothing publishes
    eng = ServeEngine(params, cfg, max_len=64, prefill_chunk=4,
                      prefix_cache_mb=64)
    toks = np.arange(4, dtype=np.int32)[None] % cfg.vocab_size
    eng.generate(toks, 2)
    assert eng.prefix_store.stats().inserts == 0


# ---------------------------------------------------------------------------
# Store invariants: refcounts, pins, budgets, executable accounting
# ---------------------------------------------------------------------------

def _fake_snap(rng, boundary, kb=1):
    arr = jnp.asarray(rng.normal(size=(kb * 256,)), jnp.float32)  # 1 KiB
    logits = jnp.asarray(rng.normal(size=(1, 8)), jnp.float32)
    return Snapshot(caches=[arr], logits=logits, pattern=("fa",),
                    p_fa=None, boundary=boundary,
                    nbytes=PXC.state_bytes([arr], logits))


def test_refcount_underflow_raises():
    store = PrefixStore(chunk=4, budget_bytes=1 << 20)
    rng = np.random.default_rng(0)
    toks = np.arange(8, dtype=np.int32)
    node = store.insert(toks, _fake_snap(rng, 4), ("router",))
    store.acquire(node)
    store.release(node)
    with pytest.raises(RuntimeError, match="refcount"):
        store.release(node)
    assert node.refs == 0


def test_eviction_respects_pins():
    rng = np.random.default_rng(1)
    one = _fake_snap(rng, 4).nbytes
    store = PrefixStore(chunk=4, budget_bytes=int(one * 2.5))
    toks = np.arange(64, dtype=np.int32)
    pinned = store.insert(toks, _fake_snap(rng, 4), ("router",))
    store.acquire(pinned)
    for b in (8, 12, 16, 20):  # overflow the budget repeatedly
        store.insert(toks, _fake_snap(rng, b), ("router",))
    assert pinned.snap is not None  # LRU-oldest yet never evicted
    assert store.device_bytes <= int(one * 2.5)
    store.release(pinned)
    store.insert(toks, _fake_snap(rng, 24), ("router",))
    assert pinned.snap is None  # unpinned → evictable again


def test_byte_budgets_honored_under_churn():
    rng = np.random.default_rng(2)
    one = _fake_snap(rng, 4).nbytes
    dev_budget, host_budget = int(one * 3.5), int(one * 2.5)
    store = PrefixStore(chunk=4, budget_bytes=dev_budget,
                        host_budget_bytes=host_budget)
    for i in range(40):
        toks = rng.integers(0, 50, size=4 * (1 + i % 5)).astype(np.int32)
        boundary = 4 * rng.integers(1, toks.size // 4 + 1)
        node = store.match(toks, ("router",))
        if node is not None:
            store.acquire(node)
            store.release(node)
        store.insert(toks, _fake_snap(rng, int(boundary)), ("router",))
        assert store.device_bytes <= dev_budget
        assert store.host_bytes <= host_budget
        s = store.stats()
        assert s.device_bytes >= 0 and s.host_bytes >= 0
    s = store.stats()
    assert s.demotions > 0 and s.drops > 0  # both tiers overflowed
    assert s.snapshots <= 6  # ≈ 3.5 device + 2.5 host snapshots


def test_restore_jits_stay_per_geometry():
    """Publish + restore across two geometries and many prompts: the
    snapshot copy jit compiles once per geometry, and the engine guard
    holds through the churn."""
    cfg, params = _setup("phi3-mini-3.8b")
    fa = tuple("fa" if k == "attn" else None for k in cfg.layer_kinds)
    mixed = _mixed_pattern(cfg)
    eng = ServeEngine(params, cfg, max_len=96, prefill_chunk=CH,
                      prefix_cache_mb=64, prefix_cache_host_mb=64)
    rng = np.random.default_rng(3)
    prefix = rng.integers(0, cfg.vocab_size, size=32).astype(np.int32)
    for ov in (fa, mixed):
        for tail in (16, 21, 32):
            toks = np.concatenate([
                prefix,
                rng.integers(0, cfg.vocab_size, size=tail).astype(np.int32)
            ])[None]
            eng.generate(toks, 2, routing_override=ov)
    assert eng.prefix_restore_cache_size() <= 2
    assert eng.prefix_store.stats().hits > 0
    eng._check_executable_guard()


def test_kv_cache_stats_reports_prefix_tier_split():
    cfg, params = _setup("phi3-mini-3.8b")
    pA, _ = _prompts(cfg)
    eng = ServeEngine(params, cfg, max_len=64, prefill_chunk=CH,
                      prefix_cache_mb=64, prefix_cache_host_mb=64)
    job = eng.prefill_chunked(jnp.asarray(pA))
    stats = kv_cache_stats(job.caches, eng.prefix_store)
    assert stats.payload_bytes > 0 and stats.overhead_bytes > 0
    assert stats.prefix_device_bytes == eng.prefix_store.device_bytes > 0
    assert stats.prefix_host_bytes == 0
    eng.prefix_store.offload_all()
    stats = kv_cache_stats(job.caches, eng.prefix_store)
    assert stats.prefix_device_bytes == 0
    assert stats.prefix_host_bytes == eng.prefix_store.host_bytes > 0
    # the prefix tiers ride alongside — total_bytes is still the live
    # decode-cache footprint only
    assert stats.total_bytes == stats.payload_bytes + stats.overhead_bytes


# ---------------------------------------------------------------------------
# Loud configuration / publication errors
# ---------------------------------------------------------------------------

def test_budget_below_one_snapshot_raises_at_config():
    cfg, params = _setup("phi3-mini-3.8b")
    with pytest.raises(ValueError, match="prefix_cache_mb.*snapshot"):
        ServeEngine(params, cfg, max_len=64, prefill_chunk=CH,
                    prefix_cache_mb=1e-4)


def test_prefix_cache_without_chunked_prefill_raises():
    cfg, params = _setup("phi3-mini-3.8b")
    with pytest.raises(ValueError, match="chunk"):
        ServeEngine(params, cfg, max_len=64, prefill_chunk=None,
                    prefix_cache_mb=64)


def test_prefix_cache_with_duo_override_raises():
    cfg, params = _setup("phi3-mini-3.8b")
    duo = tuple(("duo", 1) if k == "attn" else None
                for k in cfg.layer_kinds)
    with pytest.raises(ValueError, match="duo"):
        ServeEngine(params, cfg, max_len=64, prefill_chunk=CH,
                    routing_override=duo, prefix_cache_mb=64)


def test_publish_from_repack_fallback_raises():
    """Publication requires a chunked-eligible admission: repack state
    is full-sequence (no chunk boundaries) and prefix+suffix routing
    depends on the prompt tail."""
    cfg, params = _setup("phi3-mini-3.8b")
    eng = ServeEngine(params, cfg, max_len=64, prefill_chunk=CH,
                      routing_pooling="prefix_suffix", prefix_cache_mb=64)
    toks = np.arange(32, dtype=np.int32)[None] % cfg.vocab_size
    assert not eng.chunked_eligible(32)
    pf, pattern, caches, _ = eng.prefill_route_repack(jnp.asarray(toks))
    with pytest.raises(ValueError, match="repack fallback"):
        eng.publish_prefix(toks[0], CH, caches, pf.logits, pattern)


def test_publish_off_boundary_raises():
    cfg, params = _setup("phi3-mini-3.8b")
    pA, _ = _prompts(cfg)
    eng = ServeEngine(params, cfg, max_len=64, prefill_chunk=CH,
                      prefix_cache_mb=64)
    job = eng.prefill_chunked(jnp.asarray(pA))
    with pytest.raises(ValueError, match="boundary"):
        eng.publish_prefix(pA[0], CH + 3, job.caches, job.logits,
                           job.pattern)


# ---------------------------------------------------------------------------
# Scheduler integration: hit metrics, drain summary, bitwise streams
# ---------------------------------------------------------------------------

def test_scheduler_threads_hit_metrics_and_summary():
    cfg, params = _setup("phi3-mini-3.8b")
    rng = np.random.default_rng(4)
    prefix = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    tails = (8, 11, 5)
    reqs = [Request(rid=i, tokens=np.concatenate([
        prefix, rng.integers(0, cfg.vocab_size, size=t).astype(np.int32)
    ]), n_steps=4) for i, t in enumerate(tails)]

    eng = ServeEngine(params, cfg, max_len=64, prefill_chunk=8,
                      prefix_cache_mb=64)
    eng.scheduler(slots_per_bucket=2, chunk=4)
    for r in reqs:
        eng.submit(r)
    out = eng.drain()

    ref = ServeEngine(params, cfg, max_len=64, prefill_chunk=8)
    hit_total = 0
    for r in reqs:
        gen = ref.generate(r.tokens[None], r.n_steps)
        assert np.array_equal(out[r.rid].tokens, gen.tokens[0]), r.rid
        hit_total += out[r.rid].metrics.prefix_hit_tokens
    # the first request warms boundaries 8 and 16; later arrivals reuse
    # the shared 16-token prefix
    assert out[0].metrics.prefix_hit_tokens == 0
    assert {out[i].metrics.prefix_hit_tokens for i in (1, 2)} == {16}
    assert out.summary["prefix_hit_tokens"] == hit_total == 32
    assert 0 < out.summary["prefix_hit_fraction"] < 1
    assert out.summary["prefix_device_bytes"] > 0
    assert out.summary["prefix_host_bytes"] == 0
    assert out.summary["prefix_store"].hits == 2
    assert out.summary["kv_payload_bytes"] > 0
    eng._check_executable_guard()


def test_drain_summary_without_store():
    cfg, params = _setup("phi3-mini-3.8b")
    eng = ServeEngine(params, cfg, max_len=64, prefill_chunk=8)
    eng.submit(Request(rid=0, tokens=np.arange(12, dtype=np.int32)
                       % cfg.vocab_size, n_steps=3))
    out = eng.drain()
    assert out.summary["prefix_hit_tokens"] == 0
    assert out.summary["prefix_store"] is None
    assert out[0].tokens.shape == (3,)
