"""Pooled Pallas decode kernel + block-sparse chunked prefill
(DESIGN.md §Kernels).

Three layers of guarantees, all on CPU via interpret mode:
  1. kernel-level: ``decode_attention_pooled`` matches dense masked
     softmax on ragged FullKV / RingKV / MLA-shaped pools, including
     the degenerate rows (empty ring row, L not a block_k multiple);
  2. adapter-level: ``make_kernel_decode_attn`` hits/declines per its
     published rules and logs every decision for the engine counters;
  3. serving-level: a scheduler drain with the kernel installed is
     BITWISE equal to the dense pooled drain (incl. preemption churn)
     and adds zero decode executables beyond the geometry count.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core import modes
from repro.kernels import decode_attention_pooled
from repro.kernels.decode_attention import (PooledValid,
                                            make_kernel_decode_attn)
from repro.models import model as MD
from repro.serve import ContinuousScheduler, Request, ServeEngine

ARCHS = ["phi3-mini-3.8b", "jamba-1.5-large-398b", "deepseek-v2-236b"]


def _setup(arch):
    cfg = smoke_variant(get_config(arch))
    params = MD.init_params(jax.random.key(0), cfg)
    return cfg, params


def _ref_pooled(q, k, v, mask, scale=None):
    """Dense masked softmax — the `_dot_decode` semantics the kernel
    must reproduce.  q (B,Hq,1,Dk); k (B,Hkv,L,Dk); v (B,Hkv,L,Dv);
    mask (B,L) bool."""
    Hq, Hkv = q.shape[1], k.shape[1]
    k = jnp.repeat(k, Hq // Hkv, 1)
    v = jnp.repeat(v, Hq // Hkv, 1)
    scale = q.shape[-1] ** -0.5 if scale is None else scale
    s = jnp.einsum("bhqd,bhld->bhql", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhql,bhlv->bhqv", p, v.astype(jnp.float32))


def _rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


# ---------------------------------------------------------------------------
# 1. kernel parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("L,block_k", [(64, 16), (40, 16), (24, 8)])
def test_fullkv_ragged_parity(L, block_k):
    """FullKV pool: positions are arange, lengths ragged; L deliberately
    includes non-multiples of block_k."""
    B, Hq, Hkv, D = 4, 4, 2, 32
    ks = jax.random.split(jax.random.key(0), 3)
    q = _rand(ks[0], B, Hq, 1, D)
    k = _rand(ks[1], B, Hkv, L, D)
    v = _rand(ks[2], B, Hkv, L, D)
    lengths = jnp.asarray([1, L // 3, L - 1, L], jnp.int32)
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32)[None],
                                 (B, L))
    out = decode_attention_pooled(q, k, v, positions, lengths,
                                  block_k=block_k, interpret=True)
    ref = _ref_pooled(q, k, v, jnp.arange(L)[None, :] < lengths[:, None])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_ringkv_ragged_parity_and_empty_row():
    """RingKV pool: live entries form a contiguous prefix holding
    arbitrary absolute positions, the rest are -1.  Row 0 is an ALL
    EMPTY ring (length 0, all positions -1): the kernel must stay
    finite there while matching dense exactly on the live rows."""
    B, Hq, Hkv, L, block_k = 4, 4, 4, 20, 16
    ks = jax.random.split(jax.random.key(1), 3)
    q = _rand(ks[0], B, Hq, 1, 32)
    k = _rand(ks[1], B, Hkv, L, 32)
    v = _rand(ks[2], B, Hkv, L, 32)
    lengths = jnp.asarray([0, 5, 13, L], jnp.int32)
    rng = np.random.default_rng(0)
    pos = np.full((B, L), -1, np.int32)
    for b, n in enumerate(np.asarray(lengths)):
        pos[b, :n] = rng.choice(100, size=n, replace=False)
    positions = jnp.asarray(pos)
    out = decode_attention_pooled(q, k, v, positions, lengths,
                                  block_k=block_k, interpret=True)
    assert np.isfinite(np.asarray(out)).all()
    mask = (positions >= 0) & (jnp.arange(L)[None, :] < lengths[:, None])
    ref = _ref_pooled(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out)[1:], np.asarray(ref)[1:],
                               atol=2e-5, rtol=2e-5)


def test_mla_shaped_parity():
    """MLA absorbed decode: single kv head, Dk != Dv, explicit scale."""
    B, Hq, L = 3, 4, 40
    Dk, Dv = 48, 32          # latent+rope vs latent
    ks = jax.random.split(jax.random.key(2), 3)
    q = _rand(ks[0], B, Hq, 1, Dk)
    k = _rand(ks[1], B, 1, L, Dk)
    v = _rand(ks[2], B, 1, L, Dv)
    lengths = jnp.asarray([2, 17, L], jnp.int32)
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32)[None],
                                 (B, L))
    scale = 64 ** -0.5       # (nope+rope)^-1/2, NOT Dk^-1/2
    out = decode_attention_pooled(q, k, v, positions, lengths,
                                  block_k=16, scale=scale, interpret=True)
    ref = _ref_pooled(q, k, v, jnp.arange(L)[None, :] < lengths[:, None],
                      scale=scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# 2. adapter hit/decline protocol
# ---------------------------------------------------------------------------

def test_adapter_pooled_hit_and_decline_round_trip():
    B, Hq, Hkv, L, D = 2, 4, 2, 64, 16
    ks = jax.random.split(jax.random.key(3), 3)
    q = _rand(ks[0], B, Hq, 1, D)
    k = _rand(ks[1], B, Hkv, L, D)
    v = _rand(ks[2], B, Hkv, L, D)
    lengths = jnp.asarray([7, L], jnp.int32)
    valid = PooledValid(mask=(jnp.arange(L)[None, :]
                              < lengths[:, None])[:, None],
                        lengths=lengths)
    fn = make_kernel_decode_attn(block_k=16, min_len=16, interpret=True)
    assert fn.supports_pooled and fn.supports_scale
    out = fn(q, k, v, valid)
    assert out is not None
    assert fn.drain_log() == [("hit", "pooled")]
    ref = _ref_pooled(q, k, v, jnp.arange(L)[None, :] < lengths[:, None])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    # decline: cache extent below min_len → None, reason logged, and
    # the caller (model._dot_decode) falls back to dense
    tall = make_kernel_decode_attn(block_k=16, min_len=4 * L,
                                   interpret=True)
    assert tall(q, k, v, valid) is None
    assert tall.drain_log() == [("decline", "min_len")]
    # drain_log clears: a second drain sees only new decisions
    assert tall.drain_log() == []


def test_model_falls_back_to_dense_on_decline():
    """_dot_decode with a declining override returns the dense result
    (the decline is silent at the math layer, logged at the adapter)."""
    B, Hq, Hkv, L, D = 2, 4, 2, 32, 16
    ks = jax.random.split(jax.random.key(4), 3)
    q = _rand(ks[0], B, Hq, 1, D)
    k = _rand(ks[1], B, Hkv, L, D)
    v = _rand(ks[2], B, Hkv, L, D)
    lengths = jnp.asarray([5, L], jnp.int32)
    valid = PooledValid(mask=(jnp.arange(L)[None, :]
                              < lengths[:, None])[:, None],
                        lengths=lengths)
    dense = MD._dot_decode(q, k, v, valid.mask)
    tall = make_kernel_decode_attn(block_k=16, min_len=4 * L,
                                   interpret=True)
    with MD.use_decode_attn(tall):
        out = MD._dot_decode(q, k, v, valid)
    assert np.array_equal(np.asarray(out), np.asarray(dense))
    assert tall.drain_log() == [("decline", "min_len")]


# ---------------------------------------------------------------------------
# 3. serving parity + executable guard
# ---------------------------------------------------------------------------

def _kernel():
    return make_kernel_decode_attn(block_k=16, min_len=16,
                                   interpret=True)


def _drain(cfg, params, reqs, decode_attn, **kw):
    eng = ServeEngine(params, cfg, max_len=64, decode_attn=decode_attn,
                      **kw)
    eng.scheduler(slots_per_bucket=3, chunk=4)
    for r in reqs:
        eng.submit(r)
    out = eng.drain()
    return eng, out


@pytest.mark.parametrize("arch", ARCHS)
def test_scheduler_drain_bitwise_parity(arch):
    cfg, params = _setup(arch)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, tokens=rng.integers(
        0, cfg.vocab_size, size=(18, 26, 34)[i % 3]).astype(np.int32),
        n_steps=5) for i in range(4)]
    _, ref = _drain(cfg, params, reqs, None)
    eng, out = _drain(cfg, params, reqs, _kernel())
    for r in reqs:
        assert np.array_equal(out[r.rid].tokens, ref[r.rid].tokens), r.rid
    summary = out.summary["decode_kernel"]
    assert summary["installed"] and summary["hit_layers"] > 0
    assert summary["decline_layers"] == {}
    eng._check_executable_guard()


def test_kernel_adds_zero_executables_and_survives_churn():
    """Preemption churn over 3 geometries with the kernel installed:
    outputs bitwise-equal to the dense-pooled run, decode jit cache
    still ≤ #geometries (the kernel rides INSIDE the pooled decode
    executable — it must not add its own)."""
    cfg, params = _setup("phi3-mini-3.8b")
    kinds = cfg.layer_kinds
    fa = tuple("fa" if k == "attn" else None for k in kinds)
    sa = tuple("sa" if k == "attn" else None for k in kinds)

    def churn(decode_attn):
        rng = np.random.default_rng(4)
        eng = ServeEngine(params, cfg, max_len=64,
                          decode_attn=decode_attn)
        sched = eng.scheduler(slots_per_bucket=1, chunk=2,
                              prefill_chunks_per_tick=12)
        rid = itertools.count()
        done = {}
        for wave, prio in enumerate((0, 1, 2)):
            for p in (fa, sa):
                i = next(rid)
                eng.submit(Request(
                    rid=i, tokens=rng.integers(
                        0, cfg.vocab_size,
                        size=20 + 4 * wave).astype(np.int32),
                    n_steps=5, priority=prio, routing_override=p))
            for f in sched.tick():
                done[f.rid] = f
        for f in sched.drain().values():
            done[f.rid] = f
        return eng, sched, done

    _, _, ref = churn(None)
    eng, sched, done = churn(_kernel())
    assert len(done) == 6
    assert any(f.metrics.preemptions > 0 for f in done.values())
    for rid, f in done.items():
        assert np.array_equal(f.tokens, ref[rid].tokens), rid
    assert eng.decode_cache_size() <= sched.n_geometries()
    eng._check_executable_guard()
    assert eng.decode_kernel_summary()["hit_layers"] > 0


def test_drain_summary_metrics_counters():
    """kernel_hit / kernel_decline land in the MetricsRegistry and the
    drain summary — the satellite fixing the silent-decline gap."""
    cfg, params = _setup("phi3-mini-3.8b")
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, tokens=rng.integers(
        0, cfg.vocab_size, size=20).astype(np.int32), n_steps=4)
        for i in range(2)]
    eng, out = _drain(cfg, params, reqs, _kernel(), telemetry=True)
    s = out.summary["decode_kernel"]
    assert s["dispatches"] > 0 and s["hit_layers"] > 0
    hits = eng.telemetry.counter("decode_kernel_hit_layers_total").value
    assert hits == s["hit_layers"]
    # a declining kernel shows up in the decline counter, not hits
    eng2, out2 = _drain(cfg, params, reqs,
                        make_kernel_decode_attn(block_k=16, min_len=10 ** 6,
                                                interpret=True))
    s2 = out2.summary["decode_kernel"]
    assert s2["hit_layers"] == 0
    assert s2["decline_layers"].get("min_len", 0) > 0


# ---------------------------------------------------------------------------
# 4. block-sparse chunked prefill backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("start,C", [(0, 16), (17, 16), (40, 11)])
def test_chunk_causal_pallas_backend_parity(start, C):
    """chunk_causal_attention under the pallas backend matches the
    dense fori_loop backend at arbitrary chunk starts, including a
    chunk length that is not a block multiple."""
    B, Hq, Hkv, M, D = 2, 4, 2, 64, 32
    ks = jax.random.split(jax.random.key(5), 3)
    q = _rand(ks[0], B, Hq, C, D)
    k = _rand(ks[1], B, Hkv, M, D)
    v = _rand(ks[2], B, Hkv, M, D)
    k = k.at[:, :, start + C:].set(0)
    v = v.at[:, :, start + C:].set(0)
    ref = modes.chunk_causal_attention(q, k, v, jnp.int32(start))
    with modes.chunk_attention_backend("pallas", block=16,
                                       interpret=True):
        out = modes.chunk_causal_attention(q, k, v, jnp.int32(start))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_chunk_backend_validation_and_default():
    with pytest.raises(ValueError):
        with modes.chunk_attention_backend("nope"):
            pass
    # default resolution off-TPU is dense — CPU tier-1 stays bitwise
    assert modes._chunk_backend()[0] in ("auto", "dense", "pallas")
