"""Serving telemetry (DESIGN.md §Observability).

The load-bearing claims:
  1. telemetry OFF is free: a mixed drain with telemetry disabled is
     bitwise-identical (tokens), dispatch-identical and
     executable-guard-identical to the uninstrumented scheduler;
  2. telemetry ON is still host-side: it adds ZERO compiled
     executables — every recorded quantity is already-materialized
     host state, so no new jit keys and no device syncs in the tick
     loop;
  3. the exports are valid: the Perfetto trace round-trips through
     ``json.loads`` + schema check with a submit→retire lifetime span
     for every request in the drain, and ``metrics_text()`` parses as
     Prometheus text exposition with the per-layer routing counts and
     sa_level/pressure gauges present;
  4. everything is bounded: the histogram reservoir, the span buffer
     and the flight-recorder ring all respect their caps under churn.
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.models import model as MD
from repro.serve import (Request, SLOConfig, ServeEngine)
from repro.serve import telemetry as TM
from repro.serve import tracing as TR

ARCHS = ["phi3-mini-3.8b", "jamba-1.5-large-398b"]


def _setup(arch="phi3-mini-3.8b"):
    cfg = smoke_variant(get_config(arch))
    params = MD.init_params(jax.random.key(0), cfg)
    return cfg, params


def _prompt(cfg, n=20, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)


class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _drain(cfg, params, *, telemetry: bool, n=5, flight_ticks=512):
    """A small mixed-length drain; returns (engine, scheduler, result)."""
    eng = ServeEngine(params, cfg, max_len=64, telemetry=telemetry,
                      flight_recorder_ticks=flight_ticks)
    sched = eng.scheduler(slots_per_bucket=2, chunk=2,
                          prefill_chunks_per_tick=4)
    for i in range(n):
        sched.submit(Request(rid=i, tokens=_prompt(cfg, 12 + 5 * i, seed=i),
                             n_steps=6))
    return eng, sched, sched.drain()


# ---------------------------------------------------------------------------
# Parity: off is bitwise/guard-identical, on adds zero executables
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_telemetry_off_on_parity_and_zero_new_executables(arch):
    cfg, params = _setup(arch)
    eng0, _, res0 = _drain(cfg, params, telemetry=False)
    eng1, _, res1 = _drain(cfg, params, telemetry=True)
    assert set(res0) == set(res1)
    for rid in res0:
        assert np.array_equal(res0[rid].tokens, res1[rid].tokens), rid
        assert res0[rid].status == res1[rid].status
    # same compiled-call count, same executable census: telemetry
    # changed no jit key and forced no extra dispatch
    assert eng0.dispatch_count == eng1.dispatch_count
    assert eng0.decode_cache_size() == eng1.decode_cache_size()
    assert (eng0.prefill_chunk_cache_size()
            == eng1.prefill_chunk_cache_size())
    assert eng0._decode_keys == eng1._decode_keys
    # off engine holds no telemetry objects at all
    assert eng0.telemetry is None and eng0.tracer is None
    assert eng0.flight_recorder is None


def test_telemetry_disabled_exports_raise():
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, max_len=64)
    with pytest.raises(ValueError, match="telemetry is disabled"):
        eng.metrics_text()
    with pytest.raises(ValueError, match="telemetry is disabled"):
        eng.export_trace("/dev/null")


# ---------------------------------------------------------------------------
# Metrics: Prometheus text parses, required families present
# ---------------------------------------------------------------------------

def test_metrics_text_parses_with_routing_and_pressure_gauges():
    cfg, params = _setup()
    eng, sched, res = _drain(cfg, params, telemetry=True)
    text = eng.metrics_text()
    samples = TM.parse_prometheus_text(text)
    for family in ("flux_router_decisions_total", "flux_sa_level",
                   "flux_load_pressure", "serve_queue_depth",
                   "serve_slots_active", "serve_requests_finished_total",
                   "serve_ticks_total", "serve_ttft_seconds",
                   "flux_sa_transitions_total"):
        assert family in samples, family
    # per-layer FA/SA decision counters exist for every routed layer
    # and every decision, and the drained requests were all counted
    decisions = samples["flux_router_decisions_total"]
    layers = {lb["layer"] for lb, _ in decisions}
    assert layers == {str(i) for i in cfg.routable_layers()}
    assert {lb["decision"] for lb, _ in decisions} == {"fa", "sa"}
    per_layer = {}
    for lb, v in decisions:
        per_layer[lb["layer"]] = per_layer.get(lb["layer"], 0) + v
    # each admission lands at most one fa/sa decision per routed layer
    # (duo head-splits have no binary decision and count nothing)
    assert max(per_layer.values()) <= len(res)
    assert sum(per_layer.values()) > 0
    finished = {lb["status"]: v
                for lb, v in samples["serve_requests_finished_total"]}
    assert finished["ok"] == len(res)
    # ttft summary rendered with quantiles + sum + count
    assert "serve_ttft_seconds_count" in samples
    assert any(lb.get("quantile") == "0.95"
               for lb, _ in samples["serve_ttft_seconds"])


def test_metrics_registry_render_and_parser_rejects_garbage():
    reg = TM.MetricsRegistry()
    reg.counter("a_total", "help", kind="x").inc(3)
    reg.gauge("b").set(-1.5)
    h = reg.histogram("lat_seconds", "latency")
    for v in range(100):
        h.observe(v / 100)
    samples = TM.parse_prometheus_text(reg.render())
    assert samples["a_total"][0] == ({"kind": "x"}, 3.0)
    assert samples["b"][0][1] == -1.5
    assert samples["lat_seconds_count"][0][1] == 100.0
    with pytest.raises(ValueError):
        TM.parse_prometheus_text("not a metric line at all!\n")
    with pytest.raises(ValueError):
        TM.parse_prometheus_text("# BOGUS comment kind\n")
    with pytest.raises(ValueError):
        TM.parse_prometheus_text("")
    with pytest.raises(ValueError):
        reg.counter("a_total").inc(-1)
    with pytest.raises(ValueError):
        reg.gauge("a_total")  # kind clash on re-registration
    with pytest.raises(ValueError):
        reg.counter("bad name")


def test_histogram_reservoir_bounded_under_churn():
    h = TM.Histogram(reservoir=64)
    for v in range(10_000):
        h.observe(float(v))
    assert h.count == 10_000
    assert h.sum == float(sum(range(10_000)))
    assert h.min == 0.0 and h.max == 9999.0
    assert len(h._res) <= 64  # bounded despite 10k observations
    # decimated quantiles stay faithful to the uniform stream
    assert abs(h.percentile(50) - 5000.0) < 1500.0
    assert h.percentile(99) > h.percentile(50) > h.percentile(1)
    h.observe(float("nan"))  # NaN is not a latency
    assert h.count == 10_000


def test_quantile_helper_matches_numpy():
    xs = [3.0, 1.0, float("nan"), 2.0, 10.0]
    finite = [x for x in xs if np.isfinite(x)]
    for q in (0, 25, 50, 95, 100):
        assert TM.quantile(xs, q) == pytest.approx(
            float(np.percentile(finite, q)))
    assert np.isnan(TM.quantile([], 50))
    s = TM.summarize(xs)
    assert set(s) == {"p50", "p95", "p99"}


# ---------------------------------------------------------------------------
# Trace: json round-trip, schema, full request coverage
# ---------------------------------------------------------------------------

def test_trace_roundtrip_schema_and_request_coverage(tmp_path):
    cfg, params = _setup()
    eng, sched, res = _drain(cfg, params, telemetry=True)
    path = tmp_path / "trace.json"
    eng.export_trace(str(path))
    obj = json.loads(path.read_text())  # round-trips through json.loads
    census = TR.validate_trace(obj)
    assert census["X"] > 0 and census["M"] > 0
    # every request in the drain has a submit→retire lifetime span
    spans = TR.request_spans(obj)
    assert set(spans) == set(res)
    for rid, ev in spans.items():
        assert ev["args"]["status"] == res[rid].status
        assert ev["args"]["n_generated"] == res[rid].metrics.n_generated
        assert ev["dur"] >= 0
    # all three tracks are present and named
    pids = {e["pid"] for e in obj["traceEvents"]}
    assert {TR.PID_REQUESTS, TR.PID_SLOTS, TR.PID_SCHEDULER} <= pids
    names = {(e["pid"], e["args"]["name"])
             for e in obj["traceEvents"]
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert (TR.PID_REQUESTS, "requests") in names
    assert (TR.PID_SLOTS, "slots") in names


def test_trace_validator_rejects_malformed():
    with pytest.raises(ValueError):
        TR.validate_trace([])  # not the object form
    with pytest.raises(ValueError):
        TR.validate_trace({"traceEvents": [{"ph": "X", "pid": 1, "tid": 1,
                                            "name": "x", "ts": 0.0}]})
    with pytest.raises(ValueError):  # unknown phase
        TR.validate_trace({"traceEvents": [{"ph": "?", "pid": 1, "tid": 1,
                                            "name": "x", "ts": 0.0}]})
    with pytest.raises(ValueError):  # non-int pid
        TR.validate_trace({"traceEvents": [{"ph": "i", "pid": "1",
                                            "tid": 1, "name": "x",
                                            "ts": 0.0}]})


def test_span_tracer_budget_drops_not_grows():
    tr = TR.SpanTracer(max_events=8)
    meta = len(tr.events)  # process metadata, emitted at construction
    assert meta < 8
    for i in range(100):
        tr.instant(f"e{i}", TR.PID_SCHEDULER, 0, float(i))
    # the buffer stopped at the budget; everything past it counted
    # into ``dropped`` instead of growing the list
    assert len(tr.events) == 8
    assert tr.dropped == 100 - (8 - meta)
    obj = tr.to_json()
    assert obj["otherData"]["dropped_events"] == tr.dropped
    TR.validate_trace(obj)


# ---------------------------------------------------------------------------
# Flight recorder: ring bound under churn, events captured
# ---------------------------------------------------------------------------

def test_flight_recorder_ring_respects_bound_under_churn():
    cfg, params = _setup()
    eng, sched, res = _drain(cfg, params, telemetry=True, n=6,
                             flight_ticks=4)
    fr = eng.flight_recorder
    assert sched.ticks > 4  # the drain churned past the capacity
    assert len(fr) == 4
    assert fr.recorded == sched.ticks
    dump = fr.dump()
    assert [d["tick"] for d in dump] == sorted(d["tick"] for d in dump)
    assert dump[-1]["tick"] == sched.ticks
    last = fr.last().as_dict()
    for field in ("queue_depth", "n_active", "capacity",
                  "batch_by_geometry", "prefill_chunks", "dispatch_delta",
                  "sa_level", "pressure", "events"):
        assert field in last, field
    json.dumps(dump)  # JSON-ready incident payload


def test_flight_recorder_captures_shed_and_quarantine_events():
    cfg, params = _setup()
    clock = _Clock()
    eng = ServeEngine(params, cfg, max_len=64, telemetry=True,
                      slo=SLOConfig(max_queue=1))
    sched = eng.scheduler(slots_per_bucket=1, chunk=2,
                          prefill_chunks_per_tick=8, clock=clock)
    for i in range(3):  # queue bound 1 → rids 1, 2 shed at submit
        sched.submit(Request(rid=i, tokens=_prompt(cfg, 16, seed=i),
                             n_steps=8))
        clock.advance(0.01)
    # tick until rid 0 is resident, then poison its slot
    while not sched.n_active():
        sched.tick()
        clock.advance(0.01)
    eng.inject_fault(0)
    res = sched.drain()
    assert {f.status for f in res.values()} == {"shed", "failed"}
    events = [e for d in eng.flight_recorder.dump() for e in d["events"]]
    assert "shed:1" in events and "shed:2" in events
    assert "failed:0" in events
    # the shed/quarantine paths also counted into the registry
    samples = TM.parse_prometheus_text(eng.metrics_text())
    finished = {lb["status"]: v
                for lb, v in samples["serve_requests_finished_total"]}
    assert finished["shed"] == 2 and finished["failed"] == 1


def test_flight_recorder_capacity_validation():
    with pytest.raises(ValueError):
        TM.FlightRecorder(0)
    with pytest.raises(ValueError):
        TR.SpanTracer(max_events=0)
    with pytest.raises(ValueError):
        TM.Histogram(reservoir=1)


# ---------------------------------------------------------------------------
# SLO dial: transitions counter
# ---------------------------------------------------------------------------

def test_load_tracker_counts_transitions_both_directions():
    from repro.serve import LoadTracker
    slo = SLOConfig(adaptive_sparsity=True, pressure_patience=1,
                    max_queue=4)
    lt = LoadTracker(slo)
    assert lt.transitions == 0
    lt.observe(4, 4)  # pressure 1.0 → up
    assert lt.level == 1 and lt.transitions == 1
    lt.observe(0, 4)  # pressure 0.0 → down
    assert lt.level == 0 and lt.transitions == 2


# ---------------------------------------------------------------------------
# Exposition-format edge cases (parse_prometheus_text)
# ---------------------------------------------------------------------------

def test_empty_registry_render_is_rejected_by_parser():
    # an empty registry renders to whitespace only — a scrape of that is
    # an unscrapeable endpoint, and the validator says so explicitly
    reg = TM.MetricsRegistry()
    with pytest.raises(ValueError, match="no metric samples found"):
        TM.parse_prometheus_text(reg.render())
    with pytest.raises(ValueError, match="no metric samples found"):
        TM.parse_prometheus_text("")
    # comments alone are not samples either
    with pytest.raises(ValueError, match="no metric samples found"):
        TM.parse_prometheus_text("# TYPE foo counter\n")


def test_escaped_label_values_round_trip():
    reg = TM.MetricsRegistry()
    nasty = 'quote:" slash:\\ newline:\nend'
    reg.counter("escape_test_total", "escaping", rid=nasty).inc(3)
    text = reg.render()
    # the raw newline must be escaped, not split the sample across lines
    assert len([ln for ln in text.splitlines() if 'rid="' in ln]) == 1
    samples = TM.parse_prometheus_text(text)
    (labels, val), = samples["escape_test_total"]
    assert labels == {"rid": nasty}  # exact round-trip, not lossy
    assert val == 3.0


def test_nan_and_inf_round_trip():
    reg = TM.MetricsRegistry()
    reg.gauge("edge_nan", "x").set(float("nan"))
    reg.gauge("edge_pinf", "x").set(float("inf"))
    reg.gauge("edge_ninf", "x").set(float("-inf"))
    samples = TM.parse_prometheus_text(reg.render())
    (_, v_nan), = samples["edge_nan"]
    (_, v_pinf), = samples["edge_pinf"]
    (_, v_ninf), = samples["edge_ninf"]
    assert v_nan != v_nan  # NaN survives as NaN
    assert v_pinf == float("inf")
    assert v_ninf == float("-inf")


def test_histogram_reservoir_deterministic_for_fixed_seed():
    # overflow the reservoir so Algorithm-R replacement actually runs;
    # a fixed seed must reproduce the exact sample, a different seed a
    # (almost surely) different one — and registries derive per-metric
    # seeds, so two same-seeded registries render identically
    xs = [float(i % 97) for i in range(1000)]
    def fill(seed):
        h = TM.Histogram(reservoir=32, seed=seed)
        for x in xs:
            h.observe(x)
        return h
    a, b, c = fill(7), fill(7), fill(8)
    assert a._res == b._res
    assert a.percentile(50) == b.percentile(50)
    assert a._res != c._res
    def render(seed):
        reg = TM.MetricsRegistry(seed=seed)
        h = reg.histogram("det_ms", "d", reservoir=32)
        for x in xs:
            h.observe(x)
        return reg.render()
    assert render(1) == render(1)
    assert render(1) != render(2)


def test_tick_record_as_dict_carries_ledger_and_prefix_fields():
    r = TM.TickRecord(tick=3, t=0.5, queue_depth=1, n_active=2,
                      capacity=4, batch_by_geometry={"g0": 2},
                      prefill_chunks=1, dispatch_delta=2, sa_level=1,
                      pressure=0.25, prefix_hits=2, prefix_misses=1,
                      ledger_device_bytes=4096,
                      ledger_fragmentation_bytes=512,
                      events=("sa_level:0->1",))
    d = r.as_dict()
    assert d["prefix_hits"] == 2 and d["prefix_misses"] == 1
    assert d["ledger_device_bytes"] == 4096
    assert d["ledger_fragmentation_bytes"] == 512
    assert d["events"] == ["sa_level:0->1"]
    # mutating the dict must not alias the record's containers
    d["batch_by_geometry"]["g1"] = 9
    assert "g1" not in r.batch_by_geometry
