"""Chunked cache-resident prefill (DESIGN.md §Prefill pipeline).

The load-bearing guarantees of the route-then-stream admission:
  1. chunk-size invariance: for every arch family and every chunk size
     (single bucket, prime vs pow2, chunk > S) the chunked pipeline
     produces *identical routing decisions*, allclose last-token
     logits, and bitwise-equal greedy continuations vs the monolithic
     prefill→repack path;
  2. SA-layer peak live KV is bounded by the ring geometry during a
     long chunked prefill — never by the prompt length;
  3. chunked-prefill executables stay O(#geometries × #chunk-buckets);
  4. over-length prompts are rejected up front with actionable errors;
  5. the multi-token cache inserts are exactly equivalent to loops of
     single-token inserts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.models import model as MD
from repro.serve import (ContinuousScheduler, Request, ServeEngine,
                         chunk_plan, kv_cache)
from repro.serve.engine import kv_cache_stats

ARCHS = ["phi3-mini-3.8b", "jamba-1.5-large-398b", "deepseek-v2-236b"]
B, S, N = 2, 48, 6


def _setup(arch):
    cfg = smoke_variant(get_config(arch))
    params = MD.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    return cfg, params, toks


def _sa_pattern(cfg):
    return tuple("sa" if k == "attn" else None for k in cfg.layer_kinds)


def _mixed_pattern(cfg):
    flip, out = True, []
    for k in cfg.layer_kinds:
        out.append(("fa" if flip else "sa") if k == "attn" else None)
        flip = not flip if k == "attn" else flip
    return tuple(out)


# ---------------------------------------------------------------------------
# Chunk plan
# ---------------------------------------------------------------------------

def test_chunk_plan_exact_cover_and_bucketed():
    for seq_len in (1, 7, 16, 48, 100, 513):
        for chunk in (1, 8, 13, 16, 512):
            plan = chunk_plan(seq_len, chunk)
            # exact, contiguous, no padding
            assert plan[0][0] == 0
            assert all(plan[i][0] + plan[i][1] == plan[i + 1][0]
                       for i in range(len(plan) - 1))
            assert plan[-1][0] + plan[-1][1] == seq_len
            # sizes drawn from the static ladder {chunk} ∪ {2^k < chunk}
            for _, size in plan:
                assert size == chunk or (size < chunk
                                         and size & (size - 1) == 0)


def test_chunk_plan_rejects_bad_inputs():
    with pytest.raises(ValueError):
        chunk_plan(0, 16)
    with pytest.raises(ValueError):
        chunk_plan(16, 0)


# ---------------------------------------------------------------------------
# Multi-token insert exactness (chunk insert == loop of single inserts)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("start,C", [(0, 4), (0, 12), (6, 7), (2, 1),
                                     (9, 17)])
def test_ring_insert_chunk_matches_sequential(start, C):
    rng = np.random.default_rng(0)
    Bq, Hkv, D, sink, local = 2, 2, 4, 3, 5
    ring = sink + local
    cache = kv_cache.RingKV(
        k=jnp.zeros((Bq, Hkv, ring, D)), v=jnp.zeros((Bq, Hkv, ring, D)),
        positions=jnp.full((Bq, ring), -1, jnp.int32),
        length=jnp.zeros((Bq,), jnp.int32))
    for p in range(start):  # pre-fill history [0, start)
        kn = jnp.asarray(rng.normal(size=(Bq, Hkv, 1, D)))
        cache = kv_cache.ring_insert(cache, kn, kn, jnp.int32(p), sink,
                                     local)
    knew = jnp.asarray(rng.normal(size=(Bq, Hkv, C, D)))
    ref = cache
    for j in range(C):
        ref = kv_cache.ring_insert(ref, knew[:, :, j:j + 1],
                                   knew[:, :, j:j + 1],
                                   jnp.int32(start + j), sink, local)
    got = kv_cache.ring_insert_chunk(cache, knew, knew, jnp.int32(start),
                                     sink, local)
    assert np.array_equal(ref.positions, got.positions)
    assert np.array_equal(ref.length, got.length)
    assert np.allclose(ref.k, got.k) and np.allclose(ref.v, got.v)


@pytest.mark.parametrize("start,C", [(0, 4), (5, 9), (3, 2)])
def test_ring_latent_insert_chunk_matches_sequential(start, C):
    rng = np.random.default_rng(1)
    Bq, R, rope, sink, local = 2, 6, 4, 3, 5
    ring = sink + local
    cache = kv_cache.RingLatentKV(
        ckv=jnp.zeros((Bq, ring, R)), kr=jnp.zeros((Bq, 1, ring, rope)),
        positions=jnp.full((Bq, ring), -1, jnp.int32),
        length=jnp.zeros((Bq,), jnp.int32))
    for p in range(start):
        cn = jnp.asarray(rng.normal(size=(Bq, 1, R)))
        krn = jnp.asarray(rng.normal(size=(Bq, 1, 1, rope)))
        cache = kv_cache.ring_latent_insert(cache, cn, krn, jnp.int32(p),
                                            sink, local)
    cnew = jnp.asarray(rng.normal(size=(Bq, C, R)))
    krnew = jnp.asarray(rng.normal(size=(Bq, 1, C, rope)))
    ref = cache
    for j in range(C):
        ref = kv_cache.ring_latent_insert(ref, cnew[:, j:j + 1],
                                          krnew[:, :, j:j + 1],
                                          jnp.int32(start + j), sink, local)
    got = kv_cache.ring_latent_insert_chunk(cache, cnew, krnew,
                                            jnp.int32(start), sink, local)
    assert np.array_equal(ref.positions, got.positions)
    assert np.allclose(ref.ckv, got.ckv) and np.allclose(ref.kr, got.kr)


def test_full_insert_chunk_matches_sequential():
    rng = np.random.default_rng(2)
    Bq, Hkv, D, Smax, start, C = 2, 2, 4, 16, 3, 5
    cache = kv_cache.FullKV(
        k=jnp.zeros((Bq, Hkv, Smax, D)), v=jnp.zeros((Bq, Hkv, Smax, D)),
        length=jnp.zeros((Bq,), jnp.int32))
    knew = jnp.asarray(rng.normal(size=(Bq, Hkv, C, D)))
    ref = cache
    for j in range(C):
        ref = kv_cache.full_insert(ref, knew[:, :, j:j + 1],
                                   knew[:, :, j:j + 1], jnp.int32(start + j))
    got = kv_cache.full_insert_chunk(cache, knew, knew, jnp.int32(start))
    assert np.array_equal(ref.length, got.length)
    assert np.allclose(ref.k, got.k) and np.allclose(ref.v, got.v)


# ---------------------------------------------------------------------------
# Chunk-size invariance vs the monolithic path
# ---------------------------------------------------------------------------

# 16 = one ladder bucket (divides S); 13 = prime (ragged tail ladder,
# exercises 1-token chunks through Mamba/conv state); 64 > S.
CHUNKS = [16, 13, 64]


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("chunk", CHUNKS)
def test_chunked_matches_monolithic_routed(arch, chunk):
    """Router-driven admission: identical decisions, allclose logits,
    bitwise-equal greedy continuation vs prefill→repack."""
    cfg, params, toks = _setup(arch)
    ref_eng = ServeEngine(params, cfg, max_len=S + 16, prefill_chunk=None)
    pf, pattern, _, _ = ref_eng.prefill_route_repack(toks)
    ref = ref_eng.generate(toks, N)
    eng = ServeEngine(params, cfg, max_len=S + 16, prefill_chunk=chunk)
    job = eng.prefill_chunked(toks)
    assert job.pattern == pattern
    scale = float(jnp.abs(pf.logits).max()) + 1e-6
    assert float(jnp.abs(job.logits - pf.logits).max()) / scale < 2e-4
    gen = eng.generate(toks, N)
    assert gen.routing == ref.routing
    assert np.array_equal(gen.tokens, ref.tokens)
    eng._check_executable_guard()


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("chunk", CHUNKS)
def test_chunked_matches_monolithic_override(arch, chunk):
    """Fixed-pattern admission (mixed FA/SA geometry) matches the
    monolithic path bitwise on greedy continuations."""
    cfg, params, toks = _setup(arch)
    ov = _mixed_pattern(cfg)
    ref = ServeEngine(params, cfg, max_len=S + 16, prefill_chunk=None,
                      routing_override=ov).generate(toks, N)
    eng = ServeEngine(params, cfg, max_len=S + 16, prefill_chunk=chunk,
                      routing_override=ov)
    gen = eng.generate(toks, N)
    assert gen.routing == ref.routing
    assert np.array_equal(gen.tokens, ref.tokens)


# ---------------------------------------------------------------------------
# SA-layer peak KV is ring-bounded, not prompt-bounded
# ---------------------------------------------------------------------------

def test_sa_peak_kv_bounded_by_ring_during_chunked_prefill():
    cfg, params, _ = _setup("phi3-mini-3.8b")
    ring = cfg.flux.sink + cfg.flux.local
    max_len = 256
    payloads = {}
    for seq in (96, 224):
        toks = jax.random.randint(jax.random.key(3), (1, seq), 0,
                                  cfg.vocab_size)
        eng = ServeEngine(params, cfg, max_len=max_len, prefill_chunk=16,
                          routing_override=_sa_pattern(cfg))
        job = eng.start_chunked_prefill(toks)
        sa_bytes = []
        while not job.done:
            job.step()
            # every live cache buffer at an SA layer is ring-sized —
            # the prompt length never appears in an SA-layer shape
            for i, kind in enumerate(cfg.layer_kinds):
                if kind != "attn":
                    continue
                c = job.caches[i]
                assert isinstance(c,
                                  (kv_cache.RingKV, kv_cache.RingLatentKV))
                L = (c.ckv.shape[1]
                     if isinstance(c, kv_cache.RingLatentKV)
                     else c.k.shape[2])
                assert L == min(ring, max_len)
            sa_bytes.append(sum(
                kv_cache_stats([job.caches[i]]).payload_bytes
                for i, k in enumerate(cfg.layer_kinds) if k == "attn"))
        assert len(set(sa_bytes)) == 1  # flat across the whole stream
        payloads[seq] = sa_bytes[0]
    # identical footprint for a 96- and a 224-token prompt
    assert payloads[96] == payloads[224]


# ---------------------------------------------------------------------------
# Executable accounting
# ---------------------------------------------------------------------------

def test_prefill_executables_bounded_by_buckets():
    """Many prompt lengths, one geometry → stream executables stay
    ≤ #buckets actually used, and the engine guard holds."""
    cfg, params, _ = _setup("phi3-mini-3.8b")
    eng = ServeEngine(params, cfg, max_len=96, prefill_chunk=16,
                      routing_override=_sa_pattern(cfg))
    buckets = set()
    for seq in (17, 23, 48, 64, 80):
        toks = jax.random.randint(jax.random.key(seq), (1, seq), 0,
                                  cfg.vocab_size)
        eng.generate(toks, 2)
        buckets |= {size for _, size in chunk_plan(seq, 16)}
    assert eng.prefill_chunk_cache_size() <= len(buckets)
    eng._check_executable_guard()


def test_executable_guard_trips_on_unbucketed_chunk():
    """A stream executable the engine never registered must raise."""
    cfg, params, toks = _setup("phi3-mini-3.8b")
    eng = ServeEngine(params, cfg, max_len=S + 16, prefill_chunk=16)
    eng.generate(toks, 2)
    job = eng.prefill_chunked(toks)
    # bypass the key bookkeeping with a rogue un-bucketed chunk size
    rogue = jax.random.randint(jax.random.key(9), (B, 5), 0,
                               cfg.vocab_size)
    eng._stream_chunk(params=eng.params, tokens=rogue, caches=job.caches,
                      start=jnp.int32(S))
    with pytest.raises(RuntimeError, match="stream-chunk executable"):
        eng._check_executable_guard()


# ---------------------------------------------------------------------------
# Up-front rejection of over-length prompts
# ---------------------------------------------------------------------------

def test_generate_rejects_overlong_prompt_up_front():
    cfg, params, _ = _setup("phi3-mini-3.8b")
    eng = ServeEngine(params, cfg, max_len=32)
    toks = np.zeros((1, 40), np.int32)
    with pytest.raises(ValueError, match=r"40.*max_len=32"):
        eng.generate(toks, 2)
    assert eng.dispatch_count == 0  # rejected before any compiled call


def test_submit_rejects_overlong_prompt_up_front():
    cfg, params, _ = _setup("phi3-mini-3.8b")
    eng = ServeEngine(params, cfg, max_len=32)
    with pytest.raises(ValueError, match=r"40.*max_len=32"):
        eng.submit(Request(rid=0, tokens=np.zeros(40, np.int32),
                           n_steps=1))


def test_repack_fallback_rejects_overlong_prompt_before_repack():
    """The monolithic fallback raises at admission depth (naming length
    and limit), not inside the jitted repack trace."""
    cfg, params, _ = _setup("phi3-mini-3.8b")
    eng = ServeEngine(params, cfg, max_len=32, prefill_chunk=None)
    fa = tuple("fa" if k == "attn" else None for k in cfg.layer_kinds)
    toks = jnp.zeros((1, 40), jnp.int32)
    with pytest.raises(ValueError, match=r"seq_len=40.*max_len=32"):
        eng.prefill_route_repack(toks, fa)


# ---------------------------------------------------------------------------
# Scheduler integration: prefill chunks as tick work
# ---------------------------------------------------------------------------

def test_scheduler_chunked_admission_bitwise_and_metrics():
    cfg, params, _ = _setup("phi3-mini-3.8b")
    rng = np.random.default_rng(4)
    lens = (24, 33, 17)
    reqs = [Request(rid=i, tokens=rng.integers(
        0, cfg.vocab_size, size=lens[i]).astype(np.int32), n_steps=5)
        for i in range(len(lens))]
    eng = ServeEngine(params, cfg, max_len=64, prefill_chunk=8)
    eng.scheduler(slots_per_bucket=2, chunk=4)
    for r in reqs:
        eng.submit(r)
    out = eng.drain()
    sched = eng.scheduler()
    assert sched.prefill_chunk_ticks == sum(
        len(chunk_plan(n, 8)) for n in lens)
    ref = ServeEngine(params, cfg, max_len=64, prefill_chunk=8)
    for r in reqs:
        gen = ref.generate(r.tokens[None], r.n_steps)
        assert np.array_equal(out[r.rid].tokens, gen.tokens[0]), r.rid
        m = out[r.rid].metrics
        assert m.kv_stats is not None and m.kv_stats.payload_bytes > 0
        assert m.prefill_done_t is not None
        assert m.prefill_time >= 0 and m.slot_wait >= 0
        assert abs(m.queue_delay - (m.prefill_time + m.slot_wait)) < 1e-6
    eng._check_executable_guard()


def test_scheduler_interleaves_decode_with_long_prefill():
    """Sarathi-style mixed ticks: a resident request keeps emitting
    tokens while a long prompt's prefill streams chunk-by-chunk."""
    cfg, params, _ = _setup("phi3-mini-3.8b")
    rng = np.random.default_rng(7)
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    eng = ServeEngine(params, cfg, max_len=64, prefill_chunk=8)
    sched = eng.scheduler(slots_per_bucket=2, chunk=2, clock=clock)
    short = Request(rid=0, tokens=rng.integers(
        0, cfg.vocab_size, size=16).astype(np.int32), n_steps=10)
    eng.submit(short)
    while not sched.n_active():
        sched.tick()
    long = Request(rid=1, tokens=rng.integers(
        0, cfg.vocab_size, size=41).astype(np.int32), n_steps=2)
    eng.submit(long)
    out = eng.drain()
    m0, m1 = out[0].metrics, out[1].metrics
    # the short request produced tokens while the long prompt was still
    # streaming its prefill chunks
    assert m0.first_token_t < m1.prefill_done_t
    assert m1.prefill_time > 0
