"""SLO guardrails and graceful degradation (DESIGN.md §Robustness & SLO).

The load-bearing claims, each chaos-tested:
  1. fault isolation is *bitwise*: poisoning one decode slot retires
     exactly that request (status ``failed``) while sibling slots'
     token streams equal an unfaulted run bit for bit;
  2. ``drain`` terminates under every guardrail — deadlines, bounded
     queues, preemption budgets, faults — and every submitted request
     retires with exactly one explicit status;
  3. the degradation ladder keeps the executable-count guard intact:
     shedding, preemption and the sparsity dial never mint
     pattern-keyed recompiles.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.models import model as MD
from repro.serve import (LoadTracker, Request, SLOConfig, ServeEngine,
                         SHED_DROP_LOWEST, STATUS_CANCELLED,
                         STATUS_FAILED, STATUS_OK, STATUS_SHED,
                         STATUS_TIMEOUT, serve_batch_finished)
from repro.serve.scheduler import ContinuousScheduler

CHAOS_ARCHS = ["phi3-mini-3.8b", "jamba-1.5-large-398b"]


def _setup(arch="phi3-mini-3.8b"):
    cfg = smoke_variant(get_config(arch))
    params = MD.init_params(jax.random.key(0), cfg)
    return cfg, params


def _prompt(cfg, n=20, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)


class _Clock:
    """Manually-advanced virtual clock for deadline tests."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


# ---------------------------------------------------------------------------
# Fault isolation: bitwise sibling survival
# ---------------------------------------------------------------------------

@pytest.mark.chaos
@pytest.mark.parametrize("arch", CHAOS_ARCHS)
def test_injected_fault_quarantines_one_slot_siblings_bitwise(arch):
    cfg, params = _setup(arch)
    toks = _prompt(cfg)

    def run(fault: bool):
        eng = ServeEngine(params, cfg, max_len=64)
        sched = eng.scheduler(slots_per_bucket=3, chunk=2,
                              prefill_chunks_per_tick=8)
        for rid in range(3):
            eng.submit(Request(rid=rid, tokens=toks, n_steps=8))
        while sched.n_active() < 3:
            sched.tick()  # admit all three, decode the first chunk(s)
        if fault:
            eng.inject_fault(1)
        out = eng.drain()
        return eng, sched, out

    _, _, clean = run(fault=False)
    eng, sched, out = run(fault=True)

    assert out[1].status == STATUS_FAILED
    # quarantined mid-stream: it decoded at least one chunk before the
    # fault, and its poisoned chunk was discarded, not returned
    assert 0 < len(out[1].tokens) < 8
    # THE claim: siblings never saw the fault — bitwise identical
    for rid in (0, 2):
        assert out[rid].status == STATUS_OK
        assert np.array_equal(out[rid].tokens, clean[rid].tokens), rid
    assert out.summary["status_counts"][STATUS_FAILED] == 1
    eng._check_executable_guard()
    assert eng.decode_cache_size() <= sched.n_geometries()


@pytest.mark.chaos
def test_quarantined_slot_returns_to_pool_and_serves_again():
    cfg, params = _setup()
    toks = _prompt(cfg)
    eng = ServeEngine(params, cfg, max_len=64)
    sched = eng.scheduler(slots_per_bucket=2, chunk=2,
                          prefill_chunks_per_tick=8)
    for rid in range(2):
        eng.submit(Request(rid=rid, tokens=toks, n_steps=8))
    while sched.n_active() < 2:
        sched.tick()
    eng.inject_fault(0)
    sched.tick()  # sentinel fires: slot freed, rid 0 retired failed
    eng.submit(Request(rid=2, tokens=toks, n_steps=8))
    out = eng.drain()
    assert out[0].status == STATUS_FAILED
    assert out[1].status == out[2].status == STATUS_OK
    # the re-used slot decodes cleanly: same prompt ⇒ same stream
    assert np.array_equal(out[2].tokens, out[1].tokens)
    eng._check_executable_guard()


def test_inject_fault_requires_a_resident_request():
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, max_len=64)
    with pytest.raises(ValueError, match="no continuous scheduler"):
        eng.inject_fault(0)
    eng.scheduler(slots_per_bucket=2, chunk=2)
    eng.submit(Request(rid=0, tokens=_prompt(cfg), n_steps=4))
    with pytest.raises(ValueError, match="not resident"):
        eng.inject_fault(0)  # still waiting — nothing to poison


# ---------------------------------------------------------------------------
# Deadlines: expiry in queue, mid-prefill, mid-decode
# ---------------------------------------------------------------------------

@pytest.mark.chaos
def test_deadline_expires_in_queue():
    cfg, params = _setup()
    clk = _Clock()
    eng = ServeEngine(params, cfg, max_len=64)
    sched = eng.scheduler(slots_per_bucket=2, chunk=2, clock=clk)
    eng.submit(Request(rid=0, tokens=_prompt(cfg), n_steps=4,
                       deadline_s=5.0))
    clk.advance(6.0)  # expires before any tick ran
    out = eng.drain()
    f = out[0]
    assert f.status == STATUS_TIMEOUT
    assert len(f.tokens) == 0 and f.routing is None
    assert np.isnan(f.metrics.ttft)  # never produced a first token
    assert sched.closed


@pytest.mark.chaos
def test_deadline_expires_mid_prefill():
    cfg, params = _setup()
    clk = _Clock()
    # 48-token prompt over chunk=16 ⇒ 3 prefill chunks, one per tick
    eng = ServeEngine(params, cfg, max_len=80, prefill_chunk=16)
    sched = eng.scheduler(slots_per_bucket=2, chunk=2,
                          prefill_chunks_per_tick=1, clock=clk)
    eng.submit(Request(rid=0, tokens=_prompt(cfg, 48), n_steps=4,
                       deadline_s=10.0))
    sched.tick()  # streams chunk 1 of 3 — admission still in flight
    assert sched.waiting and sched.waiting[0].job is not None
    clk.advance(11.0)
    out = sched.drain()
    f = out[0]
    assert f.status == STATUS_TIMEOUT
    assert len(f.tokens) == 0
    # prefill had started when the deadline hit
    assert f.metrics.prefill_start_t is not None


@pytest.mark.chaos
def test_deadline_expires_mid_decode_keeps_partial_tokens():
    cfg, params = _setup()
    clk = _Clock()
    eng = ServeEngine(params, cfg, max_len=64)
    sched = eng.scheduler(slots_per_bucket=2, chunk=2,
                          prefill_chunks_per_tick=8, clock=clk)
    eng.submit(Request(rid=0, tokens=_prompt(cfg), n_steps=16,
                       deadline_s=5.0))
    while sched.n_active() < 1:
        sched.tick()
    sched.tick()  # at least one decode chunk landed
    clk.advance(6.0)
    out = sched.drain()
    f = out[0]
    assert f.status == STATUS_TIMEOUT
    assert 0 < len(f.tokens) < 16  # partial stream survives the expiry
    assert f.metrics.first_token_t is not None


def test_default_deadline_from_slo_config():
    cfg, params = _setup()
    clk = _Clock()
    eng = ServeEngine(params, cfg, max_len=64,
                      slo=SLOConfig(default_deadline_s=5.0))
    eng.scheduler(slots_per_bucket=2, chunk=2, clock=clk)
    eng.submit(Request(rid=0, tokens=_prompt(cfg), n_steps=4))
    clk.advance(6.0)
    out = eng.drain()
    assert out[0].status == STATUS_TIMEOUT
    assert out.summary["timeout_rate"] == 1.0


# ---------------------------------------------------------------------------
# Bounded queue: shed policies
# ---------------------------------------------------------------------------

@pytest.mark.chaos
def test_bounded_queue_reject_newest():
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, max_len=64,
                      slo=SLOConfig(max_queue=2))
    sched = eng.scheduler(slots_per_bucket=2, chunk=2,
                          prefill_chunks_per_tick=8)
    toks = _prompt(cfg)
    for rid in range(5):
        eng.submit(Request(rid=rid, tokens=toks, n_steps=4))
    out = eng.drain()
    statuses = {rid: out[rid].status for rid in range(5)}
    assert statuses == {0: STATUS_OK, 1: STATUS_OK, 2: STATUS_SHED,
                        3: STATUS_SHED, 4: STATUS_SHED}
    for rid in (2, 3, 4):
        assert len(out[rid].tokens) == 0
        assert np.isnan(out[rid].metrics.ttft)
    assert out.summary["shed_rate"] == pytest.approx(3 / 5)
    assert out.summary["status_counts"][STATUS_SHED] == 3
    # a shed storm cannot mint executables
    eng._check_executable_guard()
    assert eng.decode_cache_size() <= sched.n_geometries()


@pytest.mark.chaos
def test_bounded_queue_drop_lowest_priority():
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, max_len=64,
                      slo=SLOConfig(max_queue=2,
                                    shed_policy=SHED_DROP_LOWEST))
    eng.scheduler(slots_per_bucket=2, chunk=2, prefill_chunks_per_tick=8)
    toks = _prompt(cfg)
    # arrivals: prio 5, 1, 9, 0, 2 into a queue of 2 ⇒
    #   rid2 (9) displaces rid1 (1); rid3 (0) and rid4 (2) cannot
    #   displace the {5, 9} survivors and shed themselves
    for rid, prio in enumerate([5, 1, 9, 0, 2]):
        eng.submit(Request(rid=rid, tokens=toks, n_steps=4,
                           priority=prio))
    out = eng.drain()
    assert {rid for rid in out if out[rid].status == STATUS_SHED} \
        == {1, 3, 4}
    assert out[0].status == out[2].status == STATUS_OK


# ---------------------------------------------------------------------------
# Preemption budget + aging
# ---------------------------------------------------------------------------

@pytest.mark.chaos
def test_preemption_budget_exhaustion_ends_in_admission():
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, max_len=64,
                      slo=SLOConfig(preemption_budget=1))
    sched = eng.scheduler(slots_per_bucket=1, chunk=2,
                          prefill_chunks_per_tick=8)
    toks = _prompt(cfg)
    eng.submit(Request(rid=0, tokens=toks, n_steps=24, priority=0))
    while sched.n_active() < 1:
        sched.tick()
    # a higher-priority arrival spends rid 0's only preemption
    eng.submit(Request(rid=1, tokens=toks, n_steps=4, priority=5))
    done = {}
    while 1 not in done:
        for f in sched.tick():
            done[f.rid] = f
    # rid 0 re-admits; now non-evictable — a prio-9 arrival must WAIT
    while sched.n_active() < 1:
        sched.tick()
    eng.submit(Request(rid=2, tokens=toks, n_steps=4, priority=9))
    sched.tick()
    sched.tick()
    active = [i.req.rid for p in sched.pools.values()
              for i in p.active.values()]
    assert active == [0], "budget-exhausted victim must keep its slot"
    assert [i.req.rid for i in sched.waiting] == [2]
    out = eng.drain()
    assert all(out[r].status == STATUS_OK for r in range(3))
    assert out[0].metrics.preemptions == 1  # budget respected exactly
    assert out[2].metrics.preemptions == 0


def test_aging_promotes_starved_waiter_for_admission():
    cfg, params = _setup()
    clk = _Clock()
    slo = SLOConfig(aging_s=1.0)
    eng = ServeEngine(params, cfg, max_len=64, slo=slo)
    sched = eng.scheduler(slots_per_bucket=1, chunk=2,
                          prefill_chunks_per_tick=8, clock=clk)
    toks = _prompt(cfg)
    old = Request(rid=0, tokens=toks, n_steps=4, priority=0)
    young = Request(rid=1, tokens=toks, n_steps=4, priority=3)
    eng.submit(old)
    clk.advance(10.0)  # old has waited 10s ⇒ effective priority 10 > 3
    eng.submit(young)
    infs = {i.req.rid: i for i in sched.waiting}
    assert sched._eff_priority(infs[0], clk()) \
        > sched._eff_priority(infs[1], clk())
    # but preemption still compares RAW priorities (no ping-pong):
    assert sched._evictable(infs[0]) and infs[0].req.priority == 0
    out = eng.drain()
    assert all(f.status == STATUS_OK for f in out.values())
    # the aged waiter admitted first despite the lower raw priority
    assert out[0].metrics.admitted_t <= out[1].metrics.admitted_t


# ---------------------------------------------------------------------------
# Load-adaptive sparsity dial
# ---------------------------------------------------------------------------

def test_sa_biased_routing_is_monotone_and_guard_holds():
    cfg, params = _setup()
    toks = _prompt(cfg, 24)
    eng = ServeEngine(params, cfg, max_len=64)
    eng.set_sa_level(0)
    g0 = eng.generate(toks[None], 4)
    eng.set_sa_level(eng.slo.sa_level_max)
    g3 = eng.generate(toks[None], 4)
    sa0 = {i for i, p in enumerate(g0.routing) if p == "sa"}
    sa3 = {i for i, p in enumerate(g3.routing) if p == "sa"}
    # raising the rung can only move layers FA → SA, never back
    assert sa0 <= sa3
    assert not (g3.msr < g0.msr)  # nan-safe on unrouted configs
    eng._check_executable_guard()


def test_set_sa_level_clamps_to_ladder():
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, max_len=64)
    eng.set_sa_level(99)
    assert eng.sa_level == eng.slo.sa_level_max
    eng.set_sa_level(-4)
    assert eng.sa_level == 0
    assert eng.fa_threshold(0) == 0.5  # level 0 is the paper's argmax


@pytest.mark.chaos
def test_scheduler_dial_rises_under_pressure_and_serves_everything():
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, max_len=64,
                      slo=SLOConfig(adaptive_sparsity=True,
                                    pressure_patience=1))
    sched = eng.scheduler(slots_per_bucket=1, chunk=2,
                          prefill_chunks_per_tick=2)
    rng = np.random.default_rng(7)
    for rid in range(6):
        eng.submit(Request(
            rid=rid, n_steps=4,
            tokens=rng.integers(0, cfg.vocab_size, size=20
                                ).astype(np.int32)))
    levels, done = [], {}
    while sched.waiting or sched.n_active():
        for f in sched.tick():
            done[f.rid] = f
        levels.append(eng.sa_level)
    assert max(levels) >= 1, "queue pressure never engaged the dial"
    assert levels[-1] < max(levels), "dial never relaxed as load drained"
    assert sorted(done) == list(range(6))
    assert all(f.status == STATUS_OK for f in done.values())
    # the dial walks a quantized ladder: geometry set stays finite and
    # the guard arithmetic still holds
    eng._check_executable_guard()
    assert eng.decode_cache_size() <= sched.n_geometries()


def test_prefix_store_is_scoped_by_sparsity_level():
    cfg, params = _setup()
    toks = _prompt(cfg, 32)
    eng = ServeEngine(params, cfg, max_len=64, prefill_chunk=16,
                      prefix_cache_mb=8.0)
    eng.generate(toks[None], 2)  # publishes at level 0
    assert eng.prefix_store.stats().snapshots > 0
    eng.set_sa_level(2)
    eng.generate(toks[None], 2)  # other rung: decisions don't transfer
    assert eng.prefix_store.stats().hits == 0
    eng.set_sa_level(0)
    eng.generate(toks[None], 2)  # back on the published rung
    assert eng.prefix_store.stats().hits == 1
    eng._check_executable_guard()


def test_load_tracker_hysteresis():
    slo = SLOConfig(max_queue=10, adaptive_sparsity=True,
                    sa_level_max=2, pressure_patience=2)
    lt = LoadTracker(slo)
    assert lt.observe(8, 0) == 0   # hot tick 1 of 2
    assert lt.observe(8, 0) == 1   # patience met: one rung up
    assert lt.observe(8, 0) == 1   # counter reset — not 2 yet
    assert lt.observe(8, 0) == 2
    assert lt.observe(8, 0) == 2   # clamped at sa_level_max
    assert lt.observe(5, 0) == 2   # mid-band: no movement, counters reset
    assert lt.observe(1, 0) == 2   # cold tick 1 of 2
    assert lt.observe(1, 0) == 1   # one rung down
    assert lt.observe(5, 0) == 1   # mid-band holds the level


# ---------------------------------------------------------------------------
# Cancellation
# ---------------------------------------------------------------------------

def test_cancel_waiting_and_resident_requests():
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, max_len=64)
    assert eng.cancel(0) is False  # no scheduler yet
    sched = eng.scheduler(slots_per_bucket=1, chunk=2,
                          prefill_chunks_per_tick=8)
    toks = _prompt(cfg)
    eng.submit(Request(rid=0, tokens=toks, n_steps=16))
    eng.submit(Request(rid=1, tokens=toks, n_steps=16))
    while sched.n_active() < 1:
        sched.tick()
    sched.tick()  # rid 0 decodes a chunk; rid 1 waits on the full pool
    assert eng.cancel(1) is True   # cancel in queue
    assert eng.cancel(0) is True   # cancel resident (slot frees)
    assert eng.cancel(0) is False  # already retired
    out = eng.drain()
    assert out[0].status == out[1].status == STATUS_CANCELLED
    assert len(out[0].tokens) > 0   # partial stream kept
    assert len(out[1].tokens) == 0
    assert sched.n_active() == 0


# ---------------------------------------------------------------------------
# Misuse raises loudly
# ---------------------------------------------------------------------------

def test_submit_after_drain_raises():
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, max_len=64)
    eng.scheduler(slots_per_bucket=2, chunk=4)
    eng.submit(Request(rid=0, tokens=_prompt(cfg), n_steps=4))
    eng.drain()
    with pytest.raises(ValueError, match="submit after drain"):
        eng.submit(Request(rid=1, tokens=_prompt(cfg), n_steps=4))


def test_scheduler_construction_validation():
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, max_len=64)
    with pytest.raises(ValueError, match="slots_per_bucket"):
        ContinuousScheduler(eng, slots_per_bucket=0)
    with pytest.raises(ValueError, match="chunk=0"):
        ContinuousScheduler(eng, chunk=0)
    with pytest.raises(ValueError, match="prefill_chunks_per_tick"):
        ContinuousScheduler(eng, prefill_chunks_per_tick=0)


def test_slo_config_validation():
    with pytest.raises(ValueError, match="max_queue"):
        SLOConfig(max_queue=0)
    with pytest.raises(ValueError, match="shed_policy"):
        SLOConfig(shed_policy="drop_everything")
    with pytest.raises(ValueError, match="default_deadline_s"):
        SLOConfig(default_deadline_s=0.0)
    with pytest.raises(ValueError, match="preemption_budget"):
        SLOConfig(preemption_budget=-1)
    with pytest.raises(ValueError, match="aging_s"):
        SLOConfig(aging_s=-1.0)
    with pytest.raises(ValueError, match="sa_threshold_step"):
        SLOConfig(sa_threshold_step=0.0)
    with pytest.raises(ValueError, match="pressure band"):
        SLOConfig(pressure_low=0.8, pressure_high=0.2)
    with pytest.raises(ValueError, match="pressure_patience"):
        SLOConfig(pressure_patience=0)


def test_nonpositive_request_deadline_raises():
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, max_len=64)
    eng.scheduler(slots_per_bucket=2, chunk=4)
    with pytest.raises(ValueError, match="deadline_s"):
        eng.submit(Request(rid=0, tokens=_prompt(cfg), n_steps=4,
                           deadline_s=0.0))


# ---------------------------------------------------------------------------
# Batch frontend speaks the same status vocabulary
# ---------------------------------------------------------------------------

def test_serve_batch_finished_statuses_and_parity():
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, max_len=64)
    # distinct lengths ⇒ singleton buckets ⇒ per-request routing, so
    # sequential generate is an exact reference
    reqs = [Request(rid=i, tokens=_prompt(cfg, 20 + 4 * i, seed=i),
                    n_steps=4)
            for i in range(3)]
    out = serve_batch_finished(eng, reqs)
    assert all(out[i].status == STATUS_OK for i in range(3))
    for r in reqs:
        gen = eng.generate(r.tokens[None], r.n_steps)
        assert np.array_equal(out[r.rid].tokens, gen.tokens[0])


def test_serve_batch_finished_expired_deadline_times_out():
    cfg, params = _setup()
    clk = _Clock()
    eng = ServeEngine(params, cfg, max_len=64)
    reqs = [Request(rid=0, tokens=_prompt(cfg), n_steps=4,
                    deadline_s=0.5)]
    clk.advance(0.0)

    def slow_clock():
        clk.advance(1.0)  # every observation is 1s after the last
        return clk()

    out = serve_batch_finished(eng, reqs, clock=slow_clock)
    assert out[0].status == STATUS_TIMEOUT
    assert len(out[0].tokens) == 0
