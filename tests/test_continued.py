"""ContinuedTrainer (paper §5.3): router frozen, backbone adapts."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.data import mixture_iterator
from repro.models import model as MD
from repro.train import ContinuedTrainer


def test_continued_training_freezes_router_and_moves_backbone():
    cfg = smoke_variant(get_config("phi3-mini-3.8b")).replace(
        vocab_size=64)
    params = MD.init_params(jax.random.key(0), cfg)
    ct = ContinuedTrainer(cfg, total_steps=5, lr=1e-3)
    state = ct.init(params)
    router_before = jax.tree.leaves(state["router"])
    emb_before = params["embed"]
    it = mixture_iterator(cfg.vocab_size, 4, 48, seed=0)
    key = jax.random.key(1)
    for _ in range(3):
        b = next(it)
        key, sub = jax.random.split(key)
        state, m = ct.step(state, jnp.asarray(b.tokens),
                           jnp.asarray(b.labels),
                           jnp.asarray(b.loss_mask), sub)
        assert bool(jnp.isfinite(m["ce"]))
    router_after = jax.tree.leaves(state["router"])
    assert all(bool((a == b).all()) for a, b in
               zip(router_before, router_after)
               if a is not None and b is not None)
    new_params = ct.params(state)
    assert not bool((new_params["embed"] == emb_before).all())
