"""Continuous-batching scheduler invariants (DESIGN.md §Scheduler).

The three load-bearing guarantees:
  1. geometry buckets never mix routing patterns / cache geometries;
  2. the decode jit cache stays ≤ #distinct geometries served across
     admit/retire/preempt churn (the Flux executable guarantee under
     continuous batching);
  3. slot-pool outputs are bitwise-equal to the same requests served
     sequentially via ``generate`` — pooling is a pure scheduling
     transformation, not an approximation.
"""
import itertools

import jax
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.models import model as MD
from repro.serve import (ContinuousScheduler, Request, ServeEngine,
                         kv_cache)

ARCHS = ["phi3-mini-3.8b", "jamba-1.5-large-398b", "deepseek-v2-236b"]


def _setup(arch):
    cfg = smoke_variant(get_config(arch))
    params = MD.init_params(jax.random.key(0), cfg)
    return cfg, params


def _mixed_requests(cfg, n, seed=0, n_steps=7, lens=(20, 28, 36),
                    **kw):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab_size,
                                        size=lens[i % len(lens)]
                                        ).astype(np.int32),
                    n_steps=n_steps, **kw)
            for i in range(n)]


def _patterns3(cfg):
    """Three distinct geometries: all-FA, all-SA, alternating."""
    kinds = cfg.layer_kinds
    fa = tuple("fa" if k == "attn" else None for k in kinds)
    sa = tuple("sa" if k == "attn" else None for k in kinds)
    flip, mixed = True, []
    for k in kinds:
        mixed.append(("fa" if flip else "sa") if k == "attn" else None)
        flip = not flip if k == "attn" else flip
    return [fa, sa, tuple(mixed)]


# ---------------------------------------------------------------------------
# Bitwise equivalence with sequential generate
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_pooled_decode_bitwise_matches_sequential_generate(arch):
    cfg, params = _setup(arch)
    reqs = _mixed_requests(cfg, 6)
    eng = ServeEngine(params, cfg, max_len=64)
    eng.scheduler(slots_per_bucket=3, chunk=4)
    for r in reqs:
        eng.submit(r)
    out = eng.drain()
    ref = ServeEngine(params, cfg, max_len=64)
    for r in reqs:
        gen = ref.generate(r.tokens[None], r.n_steps)
        assert np.array_equal(out[r.rid].tokens, gen.tokens[0]), r.rid
        assert out[r.rid].routing == gen.routing


def test_chunk_size_does_not_change_outputs():
    """The scheduling quantum is invisible in the tokens: chunk=2 and
    chunk=8 produce identical streams (scan chunking is associative)."""
    cfg, params = _setup("phi3-mini-3.8b")
    outs = []
    for chunk in (2, 8):
        eng = ServeEngine(params, cfg, max_len=64)
        eng.scheduler(slots_per_bucket=2, chunk=chunk)
        for r in _mixed_requests(cfg, 4):
            eng.submit(r)
        outs.append({k: v.tokens for k, v in eng.drain().items()})
    assert all(np.array_equal(outs[0][k], outs[1][k]) for k in outs[0])


# ---------------------------------------------------------------------------
# Geometry-bucket purity + executable-count guard under churn
# ---------------------------------------------------------------------------

def test_buckets_never_mix_patterns_and_executables_stay_bounded():
    cfg, params = _setup("phi3-mini-3.8b")
    patterns = _patterns3(cfg)
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab_size,
                                        size=[20, 28, 24][i % 3]
                                        ).astype(np.int32),
                    n_steps=3 + (i % 5),
                    routing_override=patterns[i % 3])
            for i in range(9)]
    eng = ServeEngine(params, cfg, max_len=64)
    sched = eng.scheduler(slots_per_bucket=2, chunk=3)
    for r in reqs:
        eng.submit(r)
    out = eng.drain()
    assert sorted(out) == list(range(9))
    # ≥3 geometries churned through admit/retire
    assert sched.n_geometries() == 3
    for pool in sched.pools.values():
        # a bucket serves exactly one routing pattern = one geometry
        assert len(pool.patterns_served) == 1
        assert kv_cache.slot_geometry(pool.caches) == pool.slot_geometry()
    # THE guarantee: one decode executable per geometry, not per
    # (request, length, pattern) combination
    assert eng.decode_cache_size() <= sched.n_geometries()
    eng._check_executable_guard()


def test_executable_guard_across_preemption_churn():
    """Admit/retire/preempt over 3 geometries with tiny pools: the jit
    cache must still end ≤ #geometries."""
    cfg, params = _setup("phi3-mini-3.8b")
    patterns = _patterns3(cfg)
    rng = np.random.default_rng(4)
    eng = ServeEngine(params, cfg, max_len=64)
    # a prefill budget that admits a whole wave within its submission
    # tick: chunk-paced admission otherwise lets high-priority arrivals
    # admit *before* lower-priority slots exist, and nothing preempts
    sched = eng.scheduler(slots_per_bucket=1, chunk=2,
                          prefill_chunks_per_tick=12)
    rid = itertools.count()
    done = {}
    # staggered submission: every tick injects a higher-priority request
    # into an already-full bucket, forcing preemptions
    for wave, prio in enumerate((0, 1, 2)):
        for p in patterns:
            i = next(rid)
            eng.submit(Request(
                rid=i, tokens=rng.integers(0, cfg.vocab_size,
                                           size=20 + 4 * wave
                                           ).astype(np.int32),
                n_steps=6, priority=prio, routing_override=p))
        for f in sched.tick():
            done[f.rid] = f
    for f in sched.drain().values():
        done[f.rid] = f
    assert len(done) == 9
    assert any(f.metrics.preemptions > 0 for f in done.values())
    assert sched.n_geometries() == 3
    assert eng.decode_cache_size() <= 3
    eng._check_executable_guard()
    # preempted requests still finish with the right token count
    assert all(f.metrics.n_generated == 6 for f in done.values())


def test_preempted_request_output_is_unchanged():
    """Recompute preemption replays prompt+generated through prefill —
    the final stream must equal an uninterrupted generate."""
    cfg, params = _setup("phi3-mini-3.8b")
    sa = tuple("sa" if k == "attn" else None for k in cfg.layer_kinds)
    rng = np.random.default_rng(5)
    t_low = rng.integers(0, cfg.vocab_size, size=24).astype(np.int32)
    t_high = rng.integers(0, cfg.vocab_size, size=28).astype(np.int32)
    eng = ServeEngine(params, cfg, max_len=64)
    sched = eng.scheduler(slots_per_bucket=1, chunk=2)
    eng.submit(Request(rid=0, tokens=t_low, n_steps=10,
                       routing_override=sa, priority=0))
    # admission is chunk-paced now: tick until rid 0 is resident and has
    # decoded its first chunk, then let the high-priority arrival evict it
    while not sched.n_active():
        sched.tick()
    eng.submit(Request(rid=1, tokens=t_high, n_steps=4,
                       routing_override=sa, priority=9))
    out = sched.drain()
    assert out[0].metrics.preemptions >= 1
    ref = ServeEngine(params, cfg, max_len=64)
    for rid, toks, n in ((0, t_low, 10), (1, t_high, 4)):
        gen = ref.generate(toks[None], n, routing_override=sa)
        assert np.array_equal(out[rid].tokens, gen.tokens[0]), rid


# ---------------------------------------------------------------------------
# Frontend behavior: EOS, metrics, guards
# ---------------------------------------------------------------------------

def test_eos_retires_slot_early():
    cfg, params = _setup("phi3-mini-3.8b")
    rng = np.random.default_rng(6)
    toks = rng.integers(0, cfg.vocab_size, size=24).astype(np.int32)
    ref = ServeEngine(params, cfg, max_len=64)
    full = ref.generate(toks[None], 8).tokens[0]
    eos = int(full[2])
    eng = ServeEngine(params, cfg, max_len=64)
    eng.submit(Request(rid=0, tokens=toks, n_steps=8, eos_id=eos))
    out = eng.drain()
    stop = list(full).index(eos)
    assert out[0].tokens.tolist() == full[:stop + 1].tolist()
    assert out[0].metrics.n_generated == stop + 1


def test_frontends_agree_on_eos_and_override():
    """The same Request must yield the same tokens from serve_batch and
    from submit/drain — eos_id and routing_override included."""
    from repro.serve import serve_batch
    cfg, params = _setup("phi3-mini-3.8b")
    sa = tuple("sa" if k == "attn" else None for k in cfg.layer_kinds)
    rng = np.random.default_rng(8)
    toks = rng.integers(0, cfg.vocab_size, size=24).astype(np.int32)
    probe = ServeEngine(params, cfg, max_len=64).generate(
        toks[None], 8, routing_override=sa)
    eos = int(probe.tokens[0][3])
    req = Request(rid=0, tokens=toks, n_steps=8, eos_id=eos,
                  routing_override=sa)
    batch_out = serve_batch(ServeEngine(params, cfg, max_len=64), [req])
    eng = ServeEngine(params, cfg, max_len=64)
    eng.submit(req)
    cont_out = eng.drain()
    assert np.array_equal(batch_out[0], cont_out[0].tokens)
    assert batch_out[0].tolist()[-1] == eos


def test_request_metrics_are_recorded():
    cfg, params = _setup("phi3-mini-3.8b")
    clock = itertools.count()  # deterministic virtual seconds
    eng = ServeEngine(params, cfg, max_len=64)
    eng.scheduler(slots_per_bucket=2, chunk=4,
                  clock=lambda: float(next(clock)))
    for r in _mixed_requests(cfg, 3, n_steps=5):
        eng.submit(r)
    out = eng.drain()
    for f in out.values():
        m = f.metrics
        assert m.admitted_t is not None and m.finish_t is not None
        assert m.queue_delay >= 0
        assert m.ttft >= m.queue_delay
        assert m.finish_t >= m.first_token_t
        assert m.n_generated == 5 and m.prompt_len in (20, 28, 36)


def test_scheduler_rejects_duo_and_encoder_configs():
    cfg, params = _setup("phi3-mini-3.8b")
    eng = ServeEngine(params, cfg, max_len=64)
    sched = eng.scheduler(slots_per_bucket=1, chunk=2)
    duo = tuple(("duo", 1) if k == "attn" else None
                for k in cfg.layer_kinds)
    rng = np.random.default_rng(7)
    eng.submit(Request(rid=0,
                       tokens=rng.integers(0, cfg.vocab_size, size=20
                                           ).astype(np.int32),
                       n_steps=2, routing_override=duo))
    with pytest.raises(ValueError, match="duo"):
        sched.tick()
    cfg_a = smoke_variant(get_config("whisper-tiny"))
    params_a = MD.init_params(jax.random.key(0), cfg_a)
    with pytest.raises(ValueError, match="decoder-only"):
        ServeEngine(params_a, cfg_a, max_len=64).scheduler()


def test_slot_pool_write_rejects_geometry_mismatch():
    from repro.serve.slots import SlotPool
    cfg, params = _setup("phi3-mini-3.8b")
    fa = tuple("fa" if k == "attn" else None for k in cfg.layer_kinds)
    sa = tuple("sa" if k == "attn" else None for k in cfg.layer_kinds)
    import jax.numpy as jnp
    logits = jnp.zeros((1, cfg.vocab_size), jnp.float32)
    pool = SlotPool.create(cfg, fa, 2, 48, logits)
    wrong = kv_cache.init_decode_caches(cfg, sa, 1, 48)
    with pytest.raises(ValueError, match="geometry"):
        pool.write(0, wrong, logits, 8)
