"""Attention-mode engine vs dense oracle (all modes, GQA, offsets)."""
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import naive_attention, rand_qkv
from repro.core import modes as M

RNG = np.random.default_rng(0)
TOL = 3e-5


def masks(S):
    return {
        "full": (M.FULL, lambda qp, kp: kp <= qp),
        "bidi": (M.BIDIRECTIONAL, lambda qp, kp: (kp <= qp) | (kp > qp)),
        "window": (M.window_mode(12),
                   lambda qp, kp: (kp <= qp) & (qp - kp < 12)),
        "streaming": (M.AttnMode("streaming", sink=8, local=12),
                      lambda qp, kp: (kp <= qp)
                      & ((qp - kp < 12) | (kp < 8))),
        "triangle": (M.AttnMode("triangle", sink=8, local=12, chunk=16),
                     lambda qp, kp: (kp <= qp)
                     & (((qp - kp < 12) | (kp < 8)) | (qp >= S - 16))),
    }


@pytest.mark.parametrize("B,Hq,Hkv,S,D,bq", [
    (2, 4, 2, 64, 16, 16),
    (1, 4, 4, 50, 8, 16),   # odd seq, MHA
    (2, 8, 2, 33, 32, 8),   # odd seq, G=4
    (1, 6, 6, 64, 64, 32),
    (1, 2, 1, 96, 16, 96),  # single q block
])
def test_modes_match_oracle(B, Hq, Hkv, S, D, bq):
    q, k, v = rand_qkv(RNG, B, Hq, Hkv, S, S, D)
    for name, (mode, mask) in masks(S).items():
        out = M.attention(q, k, v, mode, block_q=bq)
        ref = naive_attention(q, k, v, mask)
        err = float(jnp.abs(out - ref).max())
        assert err < TOL, (name, err)


def test_q_offset_chunked_prefill():
    q, k, v = rand_qkv(RNG, 2, 4, 2, 64, 64, 16)
    full = naive_attention(q, k, v, lambda qp, kp: kp <= qp)
    out = M.attention(q[:, :, 48:], k, v, M.FULL, q_offset=48, block_q=8)
    assert float(jnp.abs(out - full[:, :, 48:]).max()) < TOL
    sm = M.AttnMode("streaming", sink=8, local=12)
    ref = naive_attention(q, k, v,
                          lambda qp, kp: (kp <= qp)
                          & ((qp - kp < 12) | (kp < 8)))
    out = M.attention(q[:, :, 48:], k, v, sm, q_offset=48, block_q=8)
    assert float(jnp.abs(out - ref[:, :, 48:]).max()) < TOL


def test_block_topk_keep_all_equals_full():
    q, k, v = rand_qkv(RNG, 1, 4, 2, 64, 64, 16)
    mode = M.AttnMode("block_topk", block=16, stride=4, threshold=0.0)
    out = M.attention(q, k, v, mode)
    ref = naive_attention(q, k, v, lambda qp, kp: kp <= qp)
    assert float(jnp.abs(out - ref).max()) < TOL


def test_block_topk_sparse_includes_diag_and_sink():
    """Forced diag+sink blocks: early rows (inside block 0) must match
    full attention exactly."""
    q, k, v = rand_qkv(RNG, 1, 2, 1, 128, 128, 16)
    mode = M.AttnMode("block_topk", block=16, stride=4, threshold=0.9)
    out = M.attention(q, k, v, mode)
    ref = naive_attention(q, k, v, lambda qp, kp: kp <= qp)
    assert float(jnp.abs(out[:, :, :16] - ref[:, :, :16]).max()) < TOL
    assert bool(jnp.isfinite(out).all())


def test_head_split_attention():
    q, k, v = rand_qkv(RNG, 2, 8, 4, 48, 48, 16)
    sa = M.AttnMode("streaming", sink=8, local=12)
    out = M.head_split_attention(q, k, v, 2, sa, block_q=16)
    # first 2 kv heads (4 q heads) = full; rest streaming
    full = naive_attention(q[:, :4], k[:, :2], v[:, :2],
                           lambda qp, kp: kp <= qp)
    stream = naive_attention(q[:, 4:], k[:, 2:], v[:, 2:],
                             lambda qp, kp: (kp <= qp)
                             & ((qp - kp < 12) | (kp < 8)))
    assert float(jnp.abs(out[:, :4] - full).max()) < TOL
    assert float(jnp.abs(out[:, 4:] - stream).max()) < TOL


def test_mode_flops_ordering():
    """Sparse modes must cost less than full at long S (the paper's
    premise)."""
    S, H, D = 32768, 32, 128
    fl = M.mode_flops(M.FULL, S, S, H, D)
    ssa = M.mode_flops(M.AttnMode("streaming", sink=128, local=2048),
                       S, S, H, D)
    ta = M.mode_flops(M.AttnMode("triangle", sink=128, local=2048,
                                 chunk=16384), S, S, H, D)
    xa = M.mode_flops(M.AttnMode("block_topk", block=128, stride=16,
                                 threshold=0.9), S, S, H, D)
    assert ssa < 0.2 * fl
    assert xa < 0.5 * fl
    assert ssa < ta < fl


def test_v_head_dim_mismatch():
    """MLA-style: v head dim differs from qk head dim."""
    B, Hq, Hkv, S, Dqk, Dv = 1, 4, 4, 32, 24, 16
    q = jnp.asarray(RNG.normal(size=(B, Hq, S, Dqk)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, S, Dqk)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, S, Dv)), jnp.float32)
    out = M.attention(q, k, v, M.FULL, block_q=16)
    ref = naive_attention(q, k, v, lambda qp, kp: kp <= qp)
    assert out.shape == (B, Hq, S, Dv)
    assert float(jnp.abs(out - ref).max()) < TOL
